"""Round-pipeline validation (DESIGN.md §4.7): gradient-carry rounds, the
fused server epilogue, and the compressed downlink.

* Grad-carry trajectory equality: with a deterministic oracle and fixed
  batches, the single-backprop carry rounds are BIT-EXACT against the seed
  two-backprop estimator — g^k coincides step for step, the lookahead params
  lead by exactly one step. Covered on the per-leaf tree path and the fused
  flat path, for MARINA and VR-MARINA.
* Epilogue kernels: ref == pallas_interpret under the repo's tolerance
  convention (integer payload handling exact; float accumulations to the
  1-ulp / FMA-fusion standard of DESIGN.md §4.4), and the fused epilogue
  equals the unfused dequant-mean → g+=δ → x−=γ·g composition.
* Compressed downlink: Q_down(δ_up) round-trips unbiasedly, the fused
  bidirectional round equals the manual aggregate→downlink→epilogue
  composition, and the bits ledger books both directions per wire.py (drift
  guard) — including the dense 32d broadcast on sync rounds.
* Checkpoint resume with the carried h state continues bit-exactly.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BlockRandK,
    Marina,
    VRMarina,
    make_downlink,
    make_engine,
    wire,
)
from repro.core.flat import pack, unpack
from repro.core.problems import make_synthetic_binclass, nonconvex_binclass_loss
from repro.kernels import epilogue as epi
from repro.kernels import ref

N, M, D = 4, 32, 256  # D = 2 blocks of 128


@pytest.fixture(scope="module")
def problem():
    data = make_synthetic_binclass(jax.random.PRNGKey(0), N, M, D)
    return data, jax.grad(nonconvex_binclass_loss)


def _engine(sampler="randk", **kw):
    return make_engine(
        jnp.zeros((D,)), kb=8, block=128, backend="ref", sampler=sampler, **kw
    )


def _run_seed(method, data, steps):
    st = method.init(jnp.zeros((D,)), data)
    step = jax.jit(method.step)
    params, gs, syncs = [np.asarray(st.params)], [], []
    for k in range(steps):
        st, met = step(st, jax.random.PRNGKey(k), data)
        params.append(np.asarray(st.params))
        gs.append(np.asarray(st.g))
        syncs.append(int(met.sync_round))
    return params, gs, syncs


def _g_as_vector(g):
    """Carry-mode flat g buffers unpack by truncation (zero tail pad)."""
    arr = np.asarray(g)
    return arr.reshape(-1)[:D] if arr.ndim > 1 else arr


@pytest.mark.parametrize("path", ["tree", "flat"])
def test_marina_carry_bit_exact_vs_two_backprop(problem, path):
    """Single-backprop carry rounds reproduce the seed estimator bit for
    bit: g^k equal exactly, lookahead params lead by exactly one step."""
    data, grad = problem
    comp = BlockRandK(kb=8, block=128)
    eng = _engine() if path == "flat" else None
    seed = Marina(grad, comp, gamma=0.05, p=0.3, engine=eng)
    carry = Marina(grad, comp, gamma=0.05, p=0.3, engine=eng, carry=True)

    params, gs, syncs = _run_seed(seed, data, 14)
    assert 0 in syncs and 1 in syncs  # both round types exercised

    st = carry.init(jnp.zeros((D,)), data)
    np.testing.assert_array_equal(np.asarray(st.params), params[1])
    step = jax.jit(carry.step)
    for k in range(13):
        st, met = step(st, jax.random.PRNGKey(k), data)
        assert float(met.oracle_calls) == 1.0  # ONE backprop, every round
        np.testing.assert_array_equal(_g_as_vector(st.g), gs[k])
        np.testing.assert_array_equal(np.asarray(st.params), params[k + 2])


@pytest.mark.parametrize("path", ["tree", "flat"])
def test_vr_marina_carry_bit_exact(problem, path):
    """VR carry: with deterministic oracles and mb == full batches the
    carried minibatch recursion equals the recompute path bit for bit."""
    data, grad = problem
    comp = BlockRandK(kb=8, block=128)
    eng = _engine() if path == "flat" else None
    seed = VRMarina(grad, grad, comp, gamma=0.05, p=0.3, engine=eng)
    carry = VRMarina(grad, grad, comp, gamma=0.05, p=0.3, engine=eng,
                     carry=True)

    st_s = seed.init(jnp.zeros((D,)), data)
    step_s = jax.jit(seed.step)
    params, gs = [np.asarray(st_s.params)], []
    for k in range(12):
        st_s, _ = step_s(st_s, jax.random.PRNGKey(k), data, data)
        params.append(np.asarray(st_s.params))
        gs.append(np.asarray(st_s.g))

    st = carry.init(jnp.zeros((D,)), data)
    np.testing.assert_array_equal(np.asarray(st.params), params[1])
    step = jax.jit(carry.step)
    for k in range(11):
        st, _ = step(st, jax.random.PRNGKey(k), data, data)
        np.testing.assert_array_equal(_g_as_vector(st.g), gs[k])
        np.testing.assert_array_equal(np.asarray(st.params), params[k + 2])


# ---------------------------------------------------------------------------
# Epilogue kernels: ref == pallas_interpret, fused == unfused
# ---------------------------------------------------------------------------


def _epilogue_fixtures():
    k = jax.random.PRNGKey(3)
    n, nblk, B = 3, 4, 128
    g = jax.random.normal(k, (nblk, B))
    x = jax.random.normal(jax.random.fold_in(k, 1), (nblk, B))
    x3d = jax.random.normal(jax.random.fold_in(k, 2), (n, nblk, B))
    seeds = jnp.arange(n, dtype=jnp.uint32) + 11
    return n, nblk, B, g, x, x3d, seeds


def test_epilogue_ref_matches_pallas_interpret():
    n, nblk, B, g, x, x3d, seeds = _epilogue_fixtures()
    gamma = 0.07

    # integer-payload epilogues (qsgd/natural): payloads are exact, the
    # fused float accumulation follows the identical worker-indexed order
    levels, norms = ref.qsgd_block_workers_ref(x3d, seeds, 7)
    for fn, args in (
        (epi.qsgd_epilogue, (levels, norms, g, x, gamma, 7)),
        (epi.natural_epilogue, ref.natural_block_workers_ref(x3d, seeds)
         + (g, x, gamma)),
        (epi.delta_epilogue, (x3d[0], g, x, gamma)),
        (epi.mean_epilogue, (x3d, x, gamma)),
    ):
        out_r = fn(*args, backend="ref")
        out_p = fn(*args, backend="pallas_interpret")
        for a, b in zip(out_r, out_p):
            # 1-ulp FMA-fusion tolerance (DESIGN.md §4.4)
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                rtol=1e-5, atol=1e-6,
            )

    # scatter epilogue: XLA's scatter-add may associate duplicate-offset
    # accumulation differently from the kernel's worker-major fori
    vals = jax.random.normal(jax.random.fold_in(jax.random.PRNGKey(9), 0),
                             (n, nblk, 8))
    offs = jax.random.randint(jax.random.PRNGKey(10), (n, nblk, 8), 0, B)
    out_r = epi.scatter_epilogue(vals, offs, g, x, gamma, backend="ref")
    out_p = epi.scatter_epilogue(vals, offs, g, x, gamma,
                                 backend="pallas_interpret")
    for a, b in zip(out_r, out_p):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_epilogue_fused_equals_unfused_composition():
    """One-sweep epilogue == dequant-mean kernel + the two tree.map passes
    it replaces, bit for bit on the ref backend (identical accumulation)."""
    n, nblk, B, g, x, x3d, seeds = _epilogue_fixtures()
    gamma = 0.03
    levels, norms = ref.qsgd_block_workers_ref(x3d, seeds, 7)
    g_new, x_new = epi.qsgd_epilogue(levels, norms, g, x, gamma, 7,
                                     backend="ref")
    delta = ref.qsgd_dequant_mean_ref(levels, norms, 7)
    g_ref = g + delta
    x_ref = (-gamma) * g_ref + x
    np.testing.assert_array_equal(np.asarray(g_new), np.asarray(g_ref))
    np.testing.assert_array_equal(np.asarray(x_new), np.asarray(x_ref))


def test_epilogue_preserves_x_dtype():
    _, _, _, g, x, x3d, _ = _epilogue_fixtures()
    xb = x.astype(jnp.bfloat16)
    for backend in ("ref", "pallas_interpret"):
        g_new, x_new = epi.delta_epilogue(x3d[0], g, xb, 0.01,
                                          backend=backend)
        assert g_new.dtype == jnp.float32
        assert x_new.dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# Compressed downlink
# ---------------------------------------------------------------------------


def test_downlink_roundtrip_unbiased():
    """E[Q_down(δ)] ≈ δ over keys for the qsgd downlink engine (unbiased
    broadcast — the estimator recursion stays mean-correct)."""
    eng = _engine()
    down = make_downlink(eng, sampler="qsgd", s=7)
    delta = jax.random.normal(jax.random.PRNGKey(4), (D,))
    trials = 2000

    def rt(key):
        return down.roundtrip_worker(key, delta)

    keys = jax.random.split(jax.random.PRNGKey(5), trials)
    mean = jnp.mean(jax.vmap(rt)(keys), axis=0)
    rel = float(jnp.linalg.norm(mean - delta) / jnp.linalg.norm(delta))
    # ω(block qsgd, s=7) = min(B/49, √B/7) ≈ 1.6 at B=128
    assert rel < 3.0 * np.sqrt(1.7 / trials)


def test_fused_bidirectional_round_equals_manual_composition(problem):
    """fused_round(down=...) == aggregate → Q_down roundtrip → g+=δ → x−=γg
    assembled by hand (ref backend, bit-exact)."""
    data, grad = problem
    eng = _engine()
    down = make_downlink(eng, sampler="qsgd", s=7)
    lay = eng.layout
    n = 3
    diffs = jax.random.normal(jax.random.PRNGKey(6), (n, lay.nblk, lay.block))
    g2d = jax.random.normal(jax.random.PRNGKey(7), (lay.nblk, lay.block))
    x2d = jax.random.normal(jax.random.PRNGKey(8), (lay.nblk, lay.block))
    k_up, k_down = jax.random.split(jax.random.PRNGKey(9))

    g_new, x_new = eng.fused_round(
        k_up, diffs, n, g2d, x2d, 0.05, down=down, down_key=k_down
    )

    delta_up = eng.aggregate(k_up, diffs, n)
    seeds = down.worker_seeds(k_down, 1)
    levels, norms = ref.qsgd_block_workers_ref(delta_up[None], seeds, 7)
    levels = ref.nibble_unpack_ref(
        ref.nibble_pack_ref(levels.reshape(lay.nblk, lay.block)), lay.block
    ).reshape(1, lay.nblk, lay.block)
    delta_down = ref.qsgd_dequant_mean_ref(levels, norms, 7)
    g_ref = g2d + delta_down
    x_ref = (-0.05) * g_ref + x2d
    np.testing.assert_array_equal(np.asarray(g_new), np.asarray(g_ref))
    np.testing.assert_array_equal(np.asarray(x_new), np.asarray(x_ref))


def test_downlink_ledger_drift_guard(problem):
    """StepMetrics.down_bits must equal wire.py for BOTH round types: dense
    32d on sync rounds, the Q_down payload on compressed rounds — and the
    uplink column must be untouched by the downlink."""
    data, grad = problem
    comp = BlockRandK(kb=8, block=128)
    eng = _engine()
    down = make_downlink(eng, sampler="qsgd", s=7)
    m = Marina(grad, comp, gamma=0.05, p=0.5, engine=eng, carry=True,
               down_engine=down)
    st = m.init(jnp.zeros((D,)), data)
    step = jax.jit(m.step)
    lay = eng.layout
    expect_down_q = wire.block_qsgd_bits(lay.nblk, lay.block, 7)
    expect_up_q = wire.seeded_randk_bits(lay.nblk, 8)
    seen = set()
    for k in range(20):
        st, met = step(st, jax.random.PRNGKey(k), data)
        if int(met.sync_round):
            assert float(met.down_bits) == wire.downlink_dense_bits(D)
            assert float(met.bits_per_worker) == 32.0 * D
        else:
            assert float(met.down_bits) == expect_down_q
            assert float(met.bits_per_worker) == expect_up_q
        seen.add(int(met.sync_round))
    assert seen == {0, 1}
    # the acceptance axis: total up+down of a compressed round drops ≥4×
    baseline = expect_up_q + wire.downlink_dense_bits(D)
    assert baseline / (expect_up_q + expect_down_q) >= 4.0


def test_fused_carry_rejects_tree_down_compressor(problem):
    """carry+engine consumes the downlink inside the epilogue kernel, which
    only speaks flat wire formats: a per-leaf down_compressor there must be
    refused loudly, not silently skipped while its bits are booked."""
    from repro.core import QSGD

    _, grad = problem
    with pytest.raises(ValueError, match="down_engine"):
        Marina(grad, BlockRandK(kb=8, block=128), gamma=0.05, p=0.3,
               engine=_engine(), carry=True, down_compressor=QSGD(s=7))
    with pytest.raises(ValueError, match="down_engine"):
        VRMarina(grad, grad, BlockRandK(kb=8, block=128), gamma=0.05, p=0.3,
                 engine=_engine(), carry=True, down_compressor=QSGD(s=7))


def test_no_downlink_books_dense_broadcast(problem):
    """Without a configured downlink, every round still RECEIVES the dense
    estimator — down_bits = 32d (the cost the seed ledger ignored)."""
    data, grad = problem
    m = Marina(grad, BlockRandK(kb=8, block=128), gamma=0.05, p=0.5)
    st = m.init(jnp.zeros((D,)), data)
    step = jax.jit(m.step)
    for k in range(6):
        st, met = step(st, jax.random.PRNGKey(k), data)
        assert float(met.down_bits) == 32.0 * D


# ---------------------------------------------------------------------------
# Trainer integration: ledger + checkpoint resume with the carried state
# ---------------------------------------------------------------------------


def _tiny_cfg():
    from repro.models.config import ModelConfig, dense_stack

    return ModelConfig(
        name="rs", arch_type="dense", d_model=32, num_heads=2, num_kv_heads=2,
        d_ff=64, vocab_size=64, segments=dense_stack(1),
    )


def test_trainer_down_ledger_and_carry(tmp_path):
    from repro.models import init_params
    from repro.train import TrainConfig, Trainer

    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    base = dict(
        method="marina", compressor="block_randk",
        comp_kwargs={"kb": 8, "block": 128}, gamma=0.05, n_workers=3,
        batch_per_worker=2, steps=10, log_every=5, carry_grads=True,
        downlink="qsgd", downlink_kwargs={"s": 7},
    )
    t = Trainer(cfg, TrainConfig(**base), params)
    st, hist = t.run()
    assert st.h is not None  # the carried per-worker gradients
    assert hist.down_cum[-1] > 0
    # drift guard at trainer level: down_cum is a sum of per-round wire.py
    # numbers, so it must decompose into a·dense + b·q_down with a+b = steps
    d = float(tree_dim_of(params))
    lay = t.engine.layout
    q_down = wire.block_qsgd_bits(lay.nblk, lay.block, 7)
    dense = wire.downlink_dense_bits(int(d))
    total = hist.down_cum[-1]
    solutions = [
        (a, b) for a in range(11) for b in range(11)
        if a + b == 10 and abs(a * dense + b * q_down - total) < 1.0
    ]
    assert solutions, f"down ledger {total} is not a round-count mix"


def tree_dim_of(params):
    from repro.core import tree_dim

    return tree_dim(params)


def test_trainer_rejects_downlink_on_non_marina_methods():
    """A configured downlink must refuse loudly on methods that cannot wire
    it (otherwise the broadcast stays dense while the user believes it is
    compressed)."""
    from repro.models import init_params
    from repro.train import TrainConfig, Trainer

    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="downlink"):
        Trainer(cfg, TrainConfig(method="diana", downlink="qsgd"), params)


def test_trainer_checkpoint_resume_with_carry(tmp_path):
    """Interrupt + resume mid-run with carry_grads: the carried h_i^k rides
    the checkpoint and the continuation is bit-exact vs an uninterrupted
    run, ledgers included."""
    from repro.models import init_params
    from repro.train import TrainConfig, Trainer

    cfg = _tiny_cfg()
    params = init_params(jax.random.PRNGKey(0), cfg)
    base = dict(
        method="marina", compressor="block_randk",
        comp_kwargs={"kb": 8, "block": 128}, gamma=0.05, n_workers=3,
        batch_per_worker=2, steps=10, log_every=5, carry_grads=True,
        downlink="qsgd", downlink_kwargs={"s": 7},
    )
    st_full, h_full = Trainer(cfg, TrainConfig(**base), params).run()

    ck = dict(base, ckpt_dir=str(tmp_path), ckpt_every=5)
    Trainer(cfg, dataclasses.replace(TrainConfig(**ck), steps=5), params).run()
    st_res, h_res = Trainer(cfg, TrainConfig(**ck), params).run()

    for a, b in zip(jax.tree.leaves(st_full), jax.tree.leaves(st_res)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert h_res.bits_cum[-1] == h_full.bits_cum[-1]
    assert h_res.down_cum[-1] == h_full.down_cum[-1]
    assert h_res.oracle_cum[-1] == h_full.oracle_cum[-1]
