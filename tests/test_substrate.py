"""Substrate tests: data pipeline determinism/heterogeneity, checkpoint
round-trip, trainer end-to-end on a tiny LM (loss decreases under compressed
communication), and resume-from-checkpoint equivalence."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.data import make_lm_data, make_prefix_embeddings, worker_batches
from repro.models import init_params, lm_loss
from repro.models.config import ModelConfig, dense_stack
from repro.train import TrainConfig, Trainer


def tiny_model():
    return ModelConfig(
        name="tiny",
        arch_type="dense",
        d_model=32,
        num_heads=2,
        num_kv_heads=1,
        d_ff=64,
        vocab_size=256,
        segments=dense_stack(2),
    )


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_data_deterministic():
    data = make_lm_data(4, 256, 64, seed=3)
    a = worker_batches(data, step=5, batch_per_worker=2)
    b = worker_batches(data, step=5, batch_per_worker=2)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = worker_batches(data, step=6, batch_per_worker=2)
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    assert a.shape == (4, 2, 64)
    assert int(a.min()) >= 0 and int(a.max()) < 256


def test_data_heterogeneity_across_workers():
    """Workers must have genuinely different token distributions."""
    data = make_lm_data(4, 512, 256, seed=0, heterogeneity=1.0)
    toks = np.asarray(worker_batches(data, 0, 8))  # (4, 8, 256)
    means = toks.reshape(4, -1).mean(axis=1)
    assert means.std() > 20  # worker-specific vocab regions

    iid = make_lm_data(4, 512, 256, seed=0, heterogeneity=0.0)
    toks0 = np.asarray(worker_batches(iid, 0, 8))
    means0 = toks0.reshape(4, -1).mean(axis=1)
    assert means0.std() < means.std()


def test_prefix_embeddings_shape():
    pre = make_prefix_embeddings(jax.random.PRNGKey(0), 3, 2, 8, 64)
    assert pre.shape == (3, 2, 8, 64)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "a": jnp.arange(12.0).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16), "c": jnp.int32(7)},
        "list": [jnp.zeros((5,)), jnp.full((1,), 3.5)],
    }
    save_checkpoint(str(tmp_path), 42, tree)
    assert latest_step(str(tmp_path)) == 42
    like = jax.tree.map(jnp.zeros_like, tree)
    out = load_checkpoint(str(tmp_path), 42, like)
    for x, y in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert x.dtype == y.dtype


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 0, {"w": jnp.ones((3,))})
    with pytest.raises(ValueError):
        load_checkpoint(str(tmp_path), 0, {"w": jnp.ones((4,))})


def test_checkpoint_state_dataclass(tmp_path):
    from repro.core import Marina, RandK
    from repro.core.problems import make_synthetic_binclass, nonconvex_binclass_loss

    data = make_synthetic_binclass(jax.random.PRNGKey(0), 3, 16, 10)
    m = Marina(jax.grad(nonconvex_binclass_loss), RandK(k=2), 0.1, 0.3)
    st = m.init(jnp.zeros((10,)), data)
    st, _ = jax.jit(m.step)(st, jax.random.PRNGKey(1), data)
    save_checkpoint(str(tmp_path), 1, st)
    st2 = load_checkpoint(str(tmp_path), 1, jax.tree.map(jnp.zeros_like, st))
    np.testing.assert_allclose(np.asarray(st2.params), np.asarray(st.params))
    np.testing.assert_allclose(np.asarray(st2.g), np.asarray(st.g))


# ---------------------------------------------------------------------------
# trainer end-to-end
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "method",
    [
        "vr_marina",
        pytest.param("marina", marks=pytest.mark.slow),
        pytest.param("diana", marks=pytest.mark.slow),
        "dcgd",
    ],
)
def test_trainer_loss_decreases(method):
    cfg = tiny_model()
    params = init_params(jax.random.PRNGKey(0), cfg)
    tcfg = TrainConfig(
        method=method,
        compressor="randk",
        comp_kwargs={"k": 0.05},
        gamma=0.3 if method in ("vr_marina", "marina") else 0.1,
        n_workers=3,
        batch_per_worker=4,
        mb_per_worker=2,
        steps=25,
        log_every=5,
    )
    trainer = Trainer(cfg, tcfg, params)
    state, hist = trainer.run()
    assert hist.loss[-1] < hist.loss[0]
    assert all(np.isfinite(hist.loss))
    assert hist.bits_cum[-1] > 0


def test_trainer_permk_fused_engine():
    """compressor="permk" wires the correlated engine: collection sized to the
    worker fleet, compressed-round ledger at the exact 32 + 32·(nblk·B)/n
    wire, loss finite and decreasing."""
    from repro.core import PermK

    cfg = tiny_model()
    params = init_params(jax.random.PRNGKey(0), cfg)
    tcfg = TrainConfig(
        method="vr_marina",
        compressor="permk",
        comp_kwargs={"block": 256},
        gamma=0.2,
        n_workers=4,
        batch_per_worker=4,
        mb_per_worker=2,
        steps=20,
        log_every=5,
    )
    trainer = Trainer(cfg, tcfg, params)
    assert isinstance(trainer.comp, PermK) and trainer.comp.n == 4
    assert trainer.engine is not None and trainer.engine.sampler == "permk"
    assert trainer.p == 0.25  # ζ_Q/d = 1/n
    state, hist = trainer.run()
    assert hist.loss[-1] < hist.loss[0]
    assert all(np.isfinite(hist.loss))
    # ledger: every compressed round books 32 + 32·padded/n bits, every sync
    # round 32·d — the cumulative total must decompose on that lattice.
    per_q = 32.0 + 32.0 * trainer.engine.layout.padded / 4
    d = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    total = hist.bits_cum[-1]
    n_sync = round((total - 20 * per_q) / (32.0 * d - per_q))
    assert 0 <= n_sync <= 20
    assert total == pytest.approx(n_sync * 32.0 * d + (20 - n_sync) * per_q)


def test_trainer_resume_exact(tmp_path):
    """Checkpoint + resume reproduces the uninterrupted run bit-for-bit."""
    cfg = tiny_model()
    params = init_params(jax.random.PRNGKey(0), cfg)

    def mk(steps, ckpt):
        return TrainConfig(
            method="marina",
            compressor="randk",
            comp_kwargs={"k": 0.05},
            gamma=0.2,
            n_workers=2,
            batch_per_worker=2,
            mb_per_worker=2,
            steps=steps,
            log_every=100,
            ckpt_dir=ckpt,
            ckpt_every=5,
        )

    # uninterrupted 10 steps
    t_full = Trainer(cfg, mk(10, None), params)
    state_full, hist_full = t_full.run()

    # 5 steps + checkpoint, then resume to 10
    d = str(tmp_path)
    t_a = Trainer(cfg, mk(5, d), params)
    _, hist_a = t_a.run()
    assert latest_step(d) == 4
    t_b = Trainer(cfg, mk(10, d), params)
    state_res, hist_res = t_b.run()

    for x, y in zip(jax.tree.leaves(state_res.params), jax.tree.leaves(state_full.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-6)

    # the communication/oracle ledgers must resume with the state: the
    # loss-vs-bits curves (Fig. 1/2 x-axis) continue, not restart at 0.
    assert hist_a.bits_cum[-1] > 0
    assert hist_res.bits_cum[0] == pytest.approx(hist_a.bits_cum[-1])
    assert hist_res.bits_cum[-1] == pytest.approx(hist_full.bits_cum[-1], rel=1e-6)
    assert hist_res.oracle_cum[0] == pytest.approx(hist_a.oracle_cum[-1])
    assert hist_res.oracle_cum[-1] == pytest.approx(
        hist_full.oracle_cum[-1], rel=1e-6
    )

    # legacy checkpoints (bare state tree, pre-ledger format) still resume —
    # iterates restored, ledgers zeroed — instead of raising KeyError.
    legacy = str(tmp_path / "legacy")
    save_checkpoint(legacy, 4, jax.tree.map(jnp.asarray, state_res))
    state_leg, hist_leg = Trainer(cfg, mk(10, legacy), params).run()
    assert hist_leg.bits_cum[0] == 0.0  # ledgers zeroed, but no KeyError
    assert all(np.isfinite(hist_leg.loss))
