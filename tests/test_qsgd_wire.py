"""Packed quantization wire validation (DESIGN.md §4.6).

* nibble pack/unpack is the identity on 4-bit levels and bit-exact between
  the jnp ref and the interpreted Pallas kernels (the packed uint32 words ARE
  the wire);
* the fused blockwise QSGD / natural uplinks agree bit-exactly with their
  oracles (integer levels, single-rounded norms); the fused
  dequantize-and-mean agrees to float-accumulation tolerance (same convention
  as scatter_accum — FMA fusion may differ by 1 ulp across compilation
  contexts);
* empirical ω of BlockQSGD stays within the min(B/s², √B/s) bound and both
  packed compressors are unbiased;
* quantized MARINA trajectories are identical between the per-leaf tree path
  and the fused flat path (single-leaf, block-aligned problem);
* bf16 params survive a packed quantized round;
* the wire-format accounting cannot drift: compressor payload_bits ==
  FlatEngine.payload_bits == the shared helpers in repro.core.wire.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BlockNatural,
    BlockQSGD,
    Marina,
    make_compressor,
    make_engine,
)
from repro.core import wire
from repro.core.flat import FlatEngine, make_layout
from repro.core.problems import make_synthetic_binclass, nonconvex_binclass_loss
from repro.kernels import quantize, ref


# ---------------------------------------------------------------------------
# 4-bit wire: pack/unpack
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("nblk,B", [(1, 128), (3, 256), (5, 1024)])
def test_nibble_roundtrip_identity_and_bit_exact(nblk, B):
    q = jax.random.randint(jax.random.PRNGKey(nblk), (nblk, B), -8, 8, jnp.int8)
    words_ref = ref.nibble_pack_ref(q)
    words_pal = quantize.nibble_pack(q, backend="pallas_interpret")
    assert words_ref.dtype == jnp.uint32 and words_ref.shape == (nblk, B // 8)
    np.testing.assert_array_equal(np.asarray(words_ref), np.asarray(words_pal))
    for back in (ref.nibble_unpack_ref(words_ref, B),
                 quantize.nibble_unpack(words_pal, B, backend="pallas_interpret")):
        np.testing.assert_array_equal(np.asarray(back), np.asarray(q))


def test_nibble_words_are_genuinely_packed():
    """Eight levels per uint32: the word width is B/8, and a known pattern
    lands in the expected bit positions (two's-complement nibbles)."""
    q = jnp.array([[1, -1, 7, -8, 0, 2, -3, 5]], jnp.int8)
    w = int(ref.nibble_pack_ref(q)[0, 0])
    nibs = [1, 0xF, 7, 0x8, 0, 2, 0xD, 5]
    assert w == sum(nib << (4 * t) for t, nib in enumerate(nibs))


# ---------------------------------------------------------------------------
# Fused uplink / aggregation kernels vs oracles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [1, 4])
@pytest.mark.parametrize("s", [3, 7, 15])
def test_qsgd_block_workers_bit_exact_and_bounded(n, s):
    x3d = jax.random.normal(jax.random.PRNGKey(s), (n, 3, 256)) * 2.0
    seeds = jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(2654435761) + 1
    lv_r, nm_r = ref.qsgd_block_workers_ref(x3d, seeds, s)
    lv_p, nm_p = quantize.qsgd_block_workers(
        x3d, seeds, s, backend="pallas_interpret"
    )
    np.testing.assert_array_equal(np.asarray(lv_r), np.asarray(lv_p))
    np.testing.assert_array_equal(np.asarray(nm_r), np.asarray(nm_p))
    assert lv_r.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(lv_r))) <= s  # nibble-safe for s <= 7
    # per-block norms match the data
    np.testing.assert_allclose(
        np.asarray(nm_r),
        np.linalg.norm(np.asarray(x3d, np.float64), axis=-1),
        rtol=1e-5,
    )
    dm_r = ref.qsgd_dequant_mean_ref(lv_r, nm_r, s)
    dm_p = quantize.qsgd_dequant_mean(lv_r, nm_r, s, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(dm_r), np.asarray(dm_p),
                               rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("n", [1, 3])
def test_natural_block_workers_bit_exact_power_of_two(n):
    x3d = jax.random.normal(jax.random.PRNGKey(n), (n, 2, 128)) * 5.0
    seeds = jnp.arange(n, dtype=jnp.uint32) + 9
    cd_r, sc_r = ref.natural_block_workers_ref(x3d, seeds)
    cd_p, sc_p = quantize.natural_block_workers(
        x3d, seeds, backend="pallas_interpret"
    )
    np.testing.assert_array_equal(np.asarray(cd_r), np.asarray(cd_p))
    np.testing.assert_array_equal(np.asarray(sc_r), np.asarray(sc_p))
    dm_r = ref.natural_dequant_mean_ref(cd_r, sc_r)
    dm_p = quantize.natural_dequant_mean(cd_r, sc_r, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(dm_r), np.asarray(dm_p),
                               rtol=1e-6, atol=1e-7)
    # decoded magnitudes are exact powers of two within [|x|, 2|x|]
    dec = np.asarray(ref.natural_decode_ref(cd_r[0], sc_r[0]))
    x = np.asarray(x3d[0], np.float32)
    nz = np.abs(x) > 0
    m = np.abs(dec[nz])
    assert np.all(np.exp2(np.round(np.log2(m))) == m)
    assert np.all(m <= 2 * np.abs(x[nz]) * (1 + 1e-6))
    assert np.all(m >= 0.5 * np.abs(x[nz]) * (1 - 1e-6))


def test_dequant_mean_never_materializes_dense_workers():
    """The fused aggregation jaxpr holds one (nblk, B) f32 accumulator — the
    n dequantized worker trees never appear (int8 inputs don't count: they
    ARE the payload)."""
    n, nblk, B = 16, 64, 1024
    levels = jnp.zeros((n, nblk, B), jnp.int8)
    norms = jnp.ones((n, nblk), jnp.float32)

    jaxpr = jax.make_jaxpr(
        lambda l, m: ref.qsgd_dequant_mean_ref(l, m, 7)
    )(levels, norms)

    def walk(jpr):
        for eqn in jpr.eqns:
            for v in eqn.outvars:
                shape = getattr(v.aval, "shape", ())
                dt = getattr(v.aval, "dtype", None)
                if dt == jnp.int8 or dt == jnp.uint32:
                    continue  # the payload itself
                size = int(np.prod(shape)) if shape else 1
                assert size <= 2 * nblk * B, (
                    f"dense f32 intermediate {shape} in fused dequant-mean"
                )
            for sub in eqn.params.values():
                if isinstance(sub, jax.extend.core.ClosedJaxpr):
                    walk(sub.jaxpr)
                elif isinstance(sub, (list, tuple)):
                    for s in sub:
                        if isinstance(s, jax.extend.core.ClosedJaxpr):
                            walk(s.jaxpr)

    walk(jaxpr.jaxpr)


# ---------------------------------------------------------------------------
# ω and unbiasedness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("s", [1, 3, 7])
def test_block_qsgd_empirical_omega_within_bound(s):
    """E‖Q(x)−x‖² / ‖x‖² ≤ min(B/s², √B/s) over many seeds, and E[Q(x)] ≈ x."""
    B, d = 128, 300
    comp = BlockQSGD(s=s, block=B)
    x = jax.random.normal(jax.random.PRNGKey(0), (d,))
    trials = 1500
    keys = jax.random.split(jax.random.PRNGKey(1), trials)
    qs = jax.vmap(lambda k: comp(k, x))(keys)
    err2 = jnp.sum((qs - x[None, :]) ** 2, axis=1) / jnp.sum(x**2)
    omega_hat = float(jnp.mean(err2))
    bound = comp.omega(d)
    # the bound is worst-case over x; the empirical ω must sit below it with
    # MC slack, and must not be wildly conservative at s=1 (within 50×)
    se = float(jnp.std(err2)) / np.sqrt(trials)
    assert omega_hat <= bound + 3 * se, (omega_hat, bound)
    assert omega_hat > bound / 50
    mean = jnp.mean(qs, axis=0)
    rel = float(jnp.linalg.norm(mean - x) / jnp.linalg.norm(x))
    assert rel < 3.0 * np.sqrt(bound / trials)


def test_block_natural_unbiased_omega_eighth():
    d = 400
    comp = BlockNatural(block=128)
    x = jax.random.normal(jax.random.PRNGKey(2), (d,)) * 3.0
    trials = 1500
    keys = jax.random.split(jax.random.PRNGKey(3), trials)
    qs = jax.vmap(lambda k: comp(k, x))(keys)
    err2 = jnp.sum((qs - x[None, :]) ** 2, axis=1) / jnp.sum(x**2)
    assert float(jnp.mean(err2)) <= 0.125 + 0.01
    mean = jnp.mean(qs, axis=0)
    rel = float(jnp.linalg.norm(mean - x) / jnp.linalg.norm(x))
    assert rel < 3.0 * np.sqrt(0.125 / trials)


def test_engine_ref_and_pallas_interpret_agree():
    """Full fused_delta through both backends for every packed sampler."""
    tree = {"w": jax.random.normal(jax.random.PRNGKey(3), (11, 13)),
            "b": jax.random.normal(jax.random.PRNGKey(4), (200,))}
    n = 3
    diffs = jax.tree.map(
        lambda x: jnp.stack([x * (i + 1) for i in range(n)]), tree
    )
    key = jax.random.PRNGKey(5)
    for sampler in ("qsgd", "natural", "randk_qsgd"):
        eng_ref = make_engine(tree, kb=8, block=128, backend="ref",
                              sampler=sampler, s=7)
        eng_pal = make_engine(tree, kb=8, block=128,
                              backend="pallas_interpret", sampler=sampler, s=7)
        out_ref = eng_ref.fused_delta(key, diffs, n)
        out_pal = eng_pal.fused_delta(key, diffs, n)
        for a, b in zip(jax.tree.leaves(out_ref), jax.tree.leaves(out_pal)):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-7
            )


# ---------------------------------------------------------------------------
# Tree path == flat path on a quantized MARINA run
# ---------------------------------------------------------------------------


def test_quantized_marina_tree_path_equals_flat_path():
    """Same seeds ⇒ identical trajectories between the per-leaf BlockQSGD
    path and the fused packed-wire engine (single-leaf params, d a multiple
    of the block — the samplers' murmur streams coincide)."""
    N, M, D = 4, 32, 256  # D == 2 blocks of 128
    data = make_synthetic_binclass(jax.random.PRNGKey(0), N, M, D)
    comp = BlockQSGD(s=7, block=128)
    grad = jax.grad(nonconvex_binclass_loss)

    m_tree = Marina(grad, comp, gamma=0.05, p=0.3)
    eng = FlatEngine(layout=make_layout(jnp.zeros((D,)), block=128),
                     backend="ref", sampler="qsgd", s=7)
    m_flat = Marina(grad, comp, gamma=0.05, p=0.3, engine=eng)

    st_t = m_tree.init(jnp.zeros((D,)), data)
    st_f = m_flat.init(jnp.zeros((D,)), data)
    step_t = jax.jit(m_tree.step)
    step_f = jax.jit(m_flat.step)
    saw_compressed = False
    for k in range(25):
        key = jax.random.PRNGKey(k)
        st_t, met_t = step_t(st_t, key, data)
        st_f, met_f = step_f(st_f, key, data)
        saw_compressed |= int(met_t.sync_round) == 0
        np.testing.assert_allclose(
            np.asarray(st_f.params), np.asarray(st_t.params), rtol=1e-5,
            atol=1e-6,
        )
        np.testing.assert_allclose(
            np.asarray(st_f.g), np.asarray(st_t.g), rtol=1e-5, atol=1e-6
        )
        # the ledger books the packed wire on compressed rounds
        if not int(met_f.sync_round):
            assert float(met_f.bits_per_worker) == comp.payload_bits(D)
    assert saw_compressed


def test_bf16_params_packed_quantized_round_smoke():
    """bf16 params survive fused packed-QSGD compressed rounds end to end."""
    n = 3
    params = {
        "w": jnp.ones((4, 40), jnp.bfloat16) * 0.5,
        "b": jnp.zeros((10,), jnp.bfloat16),
    }

    def loss(p, batch):
        return sum(
            jnp.sum((a.astype(jnp.float32) - b) ** 2)
            for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(batch))
        )

    batches = jax.tree.map(
        lambda x: jax.random.normal(jax.random.PRNGKey(0), (n, *x.shape)),
        params,
    )
    comp = BlockQSGD(s=7, block=128)
    eng = make_engine(params, block=128, backend="ref", sampler="qsgd", s=7)
    m = Marina(jax.grad(loss), comp, gamma=0.01, p=0.5, engine=eng)
    st = m.init(params, batches)
    step = jax.jit(m.step)
    seen = set()
    for k in range(12):
        st, met = step(st, jax.random.PRNGKey(k), batches)
        seen.add(int(met.sync_round))
    assert seen == {0, 1}
    for leaf in (*jax.tree.leaves(st.params), *jax.tree.leaves(st.g)):
        assert leaf.dtype == jnp.bfloat16
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


# ---------------------------------------------------------------------------
# Wire accounting cannot drift
# ---------------------------------------------------------------------------


def test_wire_accounting_consistency():
    d, B = 2000, 1024
    nblk = 2
    tree = {"w": jnp.ones((d,))}

    # dense block QSGD: nibble wire for s <= 7, int8 above
    for s, bits_per in ((7, 4.0), (15, 8.0), (127, 8.0)):
        comp = make_compressor("block_qsgd", s=s, block=B)
        eng = make_engine(tree, block=B, sampler="qsgd", s=s)
        want = 32.0 * nblk + bits_per * nblk * B
        assert comp.payload_bits(d) == want == eng.payload_bits()
        assert want == wire.block_qsgd_bits(nblk, B, s)

    comp = make_compressor("block_natural", block=B)
    eng = make_engine(tree, block=B, sampler="natural")
    want = 32.0 * nblk + 8.0 * nblk * B
    assert comp.payload_bits(d) == want == eng.payload_bits()

    # composition: seed + norms + packed levels; 4x fewer bits than the f32
    # flat-fused wire carrying the same sampled values at the same kb
    eng = make_engine(tree, kb=8, block=B, sampler="randk_qsgd", s=7)
    assert eng.payload_bits() == 32.0 + 32.0 * nblk + 4.0 * nblk * 8
    f32_wire = wire.seeded_randk_bits(nblk, 8)
    assert (f32_wire - 32.0) / (eng.payload_bits() - 32.0) == 4.0

    # dense quantizers use the bits-balanced p (ζ ≈ d would give p = 1 = GD)
    q4 = make_compressor("block_qsgd", s=7, block=B)
    assert abs(q4.default_p(B * nblk) - (32.0 * nblk + 4.0 * nblk * B)
               / (32.0 * nblk * B)) < 1e-12
    assert 0 < make_compressor("block_natural", block=B).default_p(d) < 0.3

    # the audited per-leaf quantizers book the byte-aligned packed wire
    assert make_compressor("qsgd", s=7).payload_bits(d) == 32.0 + 4.0 * d
    assert make_compressor("qsgd", s=8).payload_bits(d) == 32.0 + 8.0 * d
    assert make_compressor("natural").payload_bits(d) == 32.0 + 8.0 * d
    assert make_compressor("cqsgd", s=4).payload_bits(d) == 32.0 + 4.0 * d
    assert make_compressor("cqsgd", s=63).payload_bits(d) == 32.0 + 8.0 * d


def test_engine_omega_routing():
    tree = {"w": jnp.ones((2048,))}
    B = 1024
    eng_q = make_engine(tree, block=B, sampler="qsgd", s=7)
    assert eng_q.omega == min(B / 49, np.sqrt(B) / 7)
    eng_n = make_engine(tree, block=B, sampler="natural")
    assert eng_n.omega == 0.125
    eng_rq = make_engine(tree, kb=8, block=B, sampler="randk_qsgd", s=7)
    w_q = min(8 / 49, np.sqrt(8) / 7)
    assert abs(eng_rq.omega - ((1 + B / 8) * (1 + w_q) - 1)) < 1e-12
    with pytest.raises(AssertionError):
        make_engine(tree, block=B, sampler="qsgd", s=200)
