"""Per-architecture smoke tests (deliverable f): reduced variant of each family
(2 layers, d_model ≤ 512, ≤ 4 experts) — one forward + one train step on CPU,
asserting output shapes and the absence of NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import PUBLIC_TO_MODULE, all_archs, get_arch
from repro.models import forward, init_params, lm_loss, param_count, reduced

ARCHS = sorted(PUBLIC_TO_MODULE)

# backward-pass smoke of the heaviest reduced archs (MoE / recurrent stacks
# dominate jit time); the default run keeps their forward coverage and the
# backward coverage of the 6 cheaper families.
HEAVY_TRAIN = {
    "deepseek-v3-671b", "recurrentgemma-2b", "gemma3-27b", "xlstm-350m",
}
TRAIN_ARCHS = [
    pytest.param(n, marks=pytest.mark.slow) if n in HEAVY_TRAIN else n
    for n in ARCHS
]


def _setup(name, layers=2, d_model=128, B=2, S=32):
    arch = get_arch(name)
    cfg = reduced(arch.model, layers=layers, d_model=d_model)
    key = jax.random.PRNGKey(hash(name) % 2**31)
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    prefix = (
        jax.random.normal(key, (B, 8, cfg.d_model)) if arch.prefix_len else None
    )
    return arch, cfg, params, toks, prefix


@pytest.mark.parametrize("name", ARCHS)
def test_reduced_forward_shapes_and_finite(name):
    arch, cfg, params, toks, prefix = _setup(name)
    B, S = toks.shape
    P = 0 if prefix is None else prefix.shape[1]
    logits, aux, _, hidden = jax.jit(
        lambda p, t, pe: forward(p, cfg, t, pe)
    )(params, toks, prefix)
    assert logits.shape == (B, S + P, cfg.vocab_size)
    assert hidden.shape == (B, S + P, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", ARCHS)
def test_reduced_constraints(name):
    """The smoke variant respects the assignment's reduction limits."""
    arch = get_arch(name)
    cfg = reduced(arch.model, layers=2, d_model=128)
    assert cfg.num_layers <= 2
    assert cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4


@pytest.mark.parametrize("name", TRAIN_ARCHS)
def test_reduced_train_step(name):
    """One SGD step decreases loss on a memorizable batch; grads finite."""
    arch, cfg, params, toks, prefix = _setup(name)

    loss_fn = jax.jit(lambda p: lm_loss(p, cfg, toks, prefix))
    val, grads = jax.value_and_grad(lambda p: lm_loss(p, cfg, toks, prefix))(params)
    assert bool(jnp.isfinite(val))
    gnorm = jnp.sqrt(sum(jnp.sum(g**2) for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0

    lr = 0.5 / max(float(gnorm), 1.0)
    params2 = jax.tree.map(lambda p, g: p - lr * g, params, grads)
    val2 = loss_fn(params2)
    assert float(val2) < float(val)


def test_full_configs_match_assignment():
    """The full (non-reduced) configs carry the exact assigned dimensions."""
    want = {
        "deepseek-v3-671b": dict(L=61, d=7168, H=128, kv=128, V=129280),
        "qwen1.5-0.5b": dict(L=24, d=1024, H=16, kv=16, f=2816, V=151936),
        "xlstm-350m": dict(L=24, d=1024, H=4, kv=4, f=0, V=50304),
        "recurrentgemma-2b": dict(L=26, d=2560, H=10, kv=1, f=7680, V=256000),
        "llama4-scout-17b-a16e": dict(L=48, d=5120, H=40, kv=8, f=8192, V=202048),
        "musicgen-medium": dict(L=48, d=1536, H=24, kv=24, f=6144, V=2048),
        "qwen3-32b": dict(L=64, d=5120, H=64, kv=8, f=25600, V=151936),
        "internvl2-1b": dict(L=24, d=896, H=14, kv=2, f=4864, V=151655),
        "deepseek-coder-33b": dict(L=62, d=7168, H=56, kv=8, f=19200, V=32256),
        "gemma3-27b": dict(L=62, d=5376, H=32, kv=16, f=21504, V=262144),
    }
    for name, w in want.items():
        cfg = get_arch(name).model
        assert cfg.num_layers == w["L"], name
        assert cfg.d_model == w["d"], name
        assert cfg.num_heads == w["H"], name
        assert cfg.num_kv_heads == w["kv"], name
        assert cfg.vocab_size == w["V"], name
        if "f" in w:
            assert cfg.d_ff == w["f"], name
    # MoE specifics
    ds = get_arch("deepseek-v3-671b").model.moe
    assert ds.num_experts == 256 and ds.top_k == 8 and ds.d_expert == 2048
    ll = get_arch("llama4-scout-17b-a16e").model.moe
    assert ll.num_experts == 16 and ll.top_k == 1
    # gemma3 local:global = 5:1
    g = get_arch("gemma3-27b").model
    kinds = [l.mixer for s in g.segments for l in s.period for _ in range(1)]
    assert kinds.count("attn") == 1 and kinds.count("attn_local") == 7  # per period set
    # recurrentgemma 1 attn : 2 recurrent
    r = get_arch("recurrentgemma-2b").model
    period = r.segments[0].period
    assert [l.mixer for l in period] == ["rglru", "rglru", "attn_local"]


def test_long_context_eligibility():
    archs = all_archs()
    runs_long = {n for n, a in archs.items() if a.runs_long_context}
    assert runs_long == {"xlstm-350m", "recurrentgemma-2b", "gemma3-27b"}


@pytest.mark.slow
def test_param_counts_full_configs_order_of_magnitude():
    """Sanity: full-config parameter counts land near the published sizes
    (counted analytically — no allocation)."""
    import repro.launch.param_math as pm

    approx = {
        "deepseek-v3-671b": (550e9, 800e9),
        "qwen1.5-0.5b": (0.3e9, 0.8e9),
        "xlstm-350m": (0.2e9, 0.6e9),
        "recurrentgemma-2b": (2.0e9, 3.5e9),
        "llama4-scout-17b-a16e": (80e9, 130e9),
        "musicgen-medium": (1.2e9, 2.5e9),
        "qwen3-32b": (28e9, 40e9),
        "internvl2-1b": (0.4e9, 1.0e9),
        "deepseek-coder-33b": (28e9, 40e9),
        "gemma3-27b": (22e9, 32e9),
    }
    for name, (lo, hi) in approx.items():
        n = pm.count_params(get_arch(name).model)
        assert lo <= n <= hi, f"{name}: {n/1e9:.1f}B outside [{lo/1e9}, {hi/1e9}]"
