"""Property tests for the quantization operators of Def. 1.1.

Checks the two defining properties (unbiasedness and the ω variance bound),
the expected-density bound, and mechanical invariants (fixed payload shapes,
round-trip support).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import (
    Identity,
    NaturalCompression,
    QSGD,
    RandK,
    SharedRandK,
    TopK,
    make_compressor,
    tree_omega,
    tree_roundtrip,
)
from repro.core.compressors import tree_compress, tree_decompress

UNBIASED = [
    Identity(),
    RandK(k=1),
    RandK(k=5),
    RandK(k=0.25),
    SharedRandK(k=3),
    QSGD(s=1),
    QSGD(s=4),
    NaturalCompression(),
]


def _mc_moments(comp, x, trials=4000, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), trials)
    qs = jax.vmap(lambda k: comp(k, x))(keys)
    mean = jnp.mean(qs, axis=0)
    var = jnp.mean(jnp.sum((qs - x[None]) ** 2, axis=-1))
    return mean, var


@pytest.mark.parametrize("comp", UNBIASED, ids=lambda c: f"{c.name}-{getattr(c,'k',getattr(c,'s',''))}")
def test_unbiased_and_variance_bound(comp):
    d = 24
    x = jax.random.normal(jax.random.PRNGKey(7), (d,))
    mean, var = _mc_moments(comp, x)
    omega = comp.omega(d)
    nx2 = float(jnp.sum(x**2))
    # E[Q(x)] = x  (5 sigma Monte-Carlo tolerance)
    se = np.sqrt(max(omega, 1e-12) * nx2 / 4000) + 1e-6
    np.testing.assert_allclose(np.asarray(mean), np.asarray(x), atol=6 * se + 1e-5)
    # E||Q(x) - x||^2 <= omega ||x||^2 (with MC slack)
    assert float(var) <= omega * nx2 * 1.15 + 1e-6


@pytest.mark.parametrize("comp", UNBIASED, ids=lambda c: c.name)
def test_expected_density(comp):
    d = 64
    x = jax.random.normal(jax.random.PRNGKey(3), (d,))
    keys = jax.random.split(jax.random.PRNGKey(0), 500)
    nnz = jax.vmap(lambda k: jnp.sum(comp(k, x) != 0.0))(keys)
    assert float(jnp.mean(nnz)) <= comp.expected_density(d) + 1e-6


def test_randk_exact_support():
    comp = RandK(k=6)
    x = jnp.arange(1.0, 33.0)
    q = comp(jax.random.PRNGKey(0), x)
    assert int(jnp.sum(q != 0)) == 6
    # retained values scaled by d/K
    nz = q[q != 0]
    orig = x[q != 0]
    np.testing.assert_allclose(np.asarray(nz), np.asarray(orig) * 32 / 6, rtol=1e-6)


def test_topk_is_greedy_and_biased():
    comp = TopK(k=3)
    x = jnp.array([0.1, -5.0, 0.2, 3.0, -0.05, 4.0])
    q = comp(jax.random.PRNGKey(0), x)
    np.testing.assert_allclose(
        np.asarray(q), np.asarray([0.0, -5.0, 0.0, 3.0, 0.0, 4.0]), rtol=1e-6
    )
    with pytest.raises(ValueError):
        comp.omega(6)
    assert comp.delta(6) == pytest.approx(0.5)


def test_qsgd_payload_is_int8():
    comp = QSGD(s=4)
    pay = comp.compress(jax.random.PRNGKey(0), jax.random.normal(jax.random.PRNGKey(1), (50,)))
    assert pay["q"].dtype == jnp.int8


@settings(max_examples=25, deadline=None)
@given(
    d=st.integers(min_value=2, max_value=257),
    k=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_randk_roundtrip_properties(d, k, seed):
    """For any shape: support size = min(k,d), unbiased scaling, finite."""
    comp = RandK(k=k)
    x = jax.random.normal(jax.random.PRNGKey(seed), (d,))
    q = comp(jax.random.PRNGKey(seed + 1), x)
    keff = comp.k_for(d)
    assert int(jnp.sum(q != 0)) <= keff  # ties if x has zeros
    assert bool(jnp.all(jnp.isfinite(q)))
    assert q.shape == x.shape


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_natural_compression_powers_of_two(seed):
    comp = NaturalCompression()
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 10
    q = comp(jax.random.PRNGKey(seed + 1), x)
    nz = np.asarray(q[q != 0.0])
    exps = np.log2(np.abs(nz))
    np.testing.assert_allclose(exps, np.round(exps), atol=1e-5)


def test_tree_compress_roundtrip_shapes():
    tree = {
        "w": jnp.ones((8, 16)),
        "b": jnp.arange(10.0),
        "nested": {"v": jnp.ones((4, 4, 4))},
    }
    comp = RandK(k=0.125)
    out = tree_roundtrip(comp, jax.random.PRNGKey(0), tree)
    assert jax.tree.structure(out) == jax.tree.structure(tree)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        assert a.shape == b.shape
    # worst leaf: b has d=10, k=round(0.125*10)=1 -> omega = 9
    assert tree_omega(comp, tree) == pytest.approx(9.0)


def test_tree_compress_under_jit_and_vmap():
    tree = {"w": jnp.ones((6, 6)), "b": jnp.zeros((5,))}
    comp = RandK(k=2)

    @jax.jit
    def roundtrip(key, t):
        return tree_decompress(comp, tree_compress(comp, key, t), t)

    out = roundtrip(jax.random.PRNGKey(0), tree)
    assert out["w"].shape == (6, 6)

    keys = jax.random.split(jax.random.PRNGKey(1), 4)
    stacked = jax.tree.map(lambda x: jnp.stack([x] * 4), tree)
    outs = jax.vmap(roundtrip)(keys, stacked)
    assert outs["w"].shape == (4, 6, 6)


def test_registry():
    assert make_compressor("randk", k=3).k == 3
    assert make_compressor("identity").omega(10) == 0.0
    assert make_compressor("qsgd", s=2).s == 2
    with pytest.raises(ValueError):
        make_compressor("nope")


def test_shared_randk_same_mask_across_workers():
    comp = SharedRandK(k=4)
    key = jax.random.PRNGKey(0)
    x1 = jax.random.normal(jax.random.PRNGKey(1), (32,))
    x2 = jax.random.normal(jax.random.PRNGKey(2), (32,))
    q1 = comp(key, x1)
    q2 = comp(key, x2)
    np.testing.assert_array_equal(np.asarray(q1 != 0), np.asarray(q2 != 0))
