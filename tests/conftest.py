"""Ensure the tests directory is importable (for the _hyp hypothesis shim)."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
