"""Behavioural tests for MARINA / VR-MARINA / PP-MARINA (Algorithms 1-4).

Validates the paper's claims at test scale:
* Thm 2.1: MARINA with the theoretical stepsize reaches an ε-stationary point.
* §2: identity quantization ⇒ MARINA ≡ GD, bit-for-bit.
* Biasedness: E[g^{k+1} | x] ≠ ∇f(x^{k+1}) for nontrivial Q (the paper's key
  structural property) while DIANA's estimator is unbiased.
* Thm 2.2 (PŁ): linear convergence on a PŁ quadratic.
* Communication ledger: compressed rounds cost ζ_Q-proportional bits.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DCGD,
    Diana,
    ECSGD,
    Marina,
    PPMarina,
    RandK,
    TopK,
    VRMarina,
    diana_alpha,
    make_gd,
    marina_gamma,
    marina_gamma_pl,
    pp_marina_gamma,
    vr_marina_gamma,
)
from repro.core.problems import (
    BinClassData,
    binclass_full_grad,
    binclass_smoothness,
    make_synthetic_binclass,
    make_quadratic,
    quad_optimum,
    quadratic_loss,
    nonconvex_binclass_loss,
    sample_minibatch,
)

N, M, D = 5, 64, 30


@pytest.fixture(scope="module")
def problem():
    data = make_synthetic_binclass(jax.random.PRNGKey(0), N, M, D)
    L = binclass_smoothness(data)
    return data, L


def global_grad_sqnorm(x, data):
    flat = BinClassData(a=data.a.reshape(-1, D), y=data.y.reshape(-1))
    g = binclass_full_grad(x, flat)
    return float(jnp.sum(g**2))


def run(method, state, data, steps, seed=0, extra=None):
    step = jax.jit(method.step)
    for k in range(steps):
        key = jax.random.PRNGKey(seed * 100_000 + k)
        if extra is not None:
            state, met = step(state, key, data, extra(key))
        else:
            state, met = step(state, key, data)
    return state, met


def test_marina_reaches_stationarity(problem):
    data, L = problem
    comp = RandK(k=3)
    p = comp.default_p(D)
    gamma = marina_gamma(L, comp.omega(D), p, N)
    m = Marina(grad_fn=jax.grad(nonconvex_binclass_loss), compressor=comp, gamma=gamma, p=p)
    st = m.init(jnp.zeros((D,)), data)
    st, _ = run(m, st, data, 400)
    assert global_grad_sqnorm(st.params, data) < 1e-3


def test_marina_identity_equals_gd(problem):
    data, L = problem
    gd = make_gd(jax.grad(nonconvex_binclass_loss), gamma=1.0 / L)
    st = gd.init(jnp.zeros((D,)), data)
    st, _ = run(gd, st, data, 60)
    x = jnp.zeros((D,))
    for _ in range(60):
        gs = jax.vmap(jax.grad(nonconvex_binclass_loss), in_axes=(None, 0))(x, data)
        x = x - (1.0 / L) * jnp.mean(gs, 0)
    np.testing.assert_allclose(np.asarray(st.params), np.asarray(x), atol=1e-5)


def test_marina_estimator_is_biased_diana_is_not(problem):
    """E[g^{k+1} | x^{k+1}] != grad f(x^{k+1}) for MARINA on compressed rounds,
    while DIANA's estimator is unbiased. Monte-Carlo over compressor keys."""
    data, L = problem
    comp = RandK(k=2)
    x_old = jnp.ones((D,)) * 0.3
    g_old = jnp.zeros((D,))  # deliberately wrong server estimate
    gamma = 0.1
    x_new = x_old - gamma * g_old

    grads_new = jax.vmap(jax.grad(nonconvex_binclass_loss), in_axes=(None, 0))(x_new, data)
    grads_old = jax.vmap(jax.grad(nonconvex_binclass_loss), in_axes=(None, 0))(x_old, data)
    diffs = grads_new - grads_old
    true_grad = jnp.mean(grads_new, 0)

    def marina_estimate(key):
        keys = jax.random.split(key, N)
        qs = jax.vmap(lambda k, v: comp(k, v))(keys, diffs)
        return g_old + jnp.mean(qs, 0)

    keys = jax.random.split(jax.random.PRNGKey(0), 3000)
    est = jnp.mean(jax.vmap(marina_estimate)(keys), axis=0)
    # E[g] = g_old + mean(diffs) which differs from true grad since g_old wrong
    bias = float(jnp.linalg.norm(est - true_grad))
    expected_bias = float(jnp.linalg.norm(g_old + jnp.mean(diffs, 0) - true_grad))
    assert bias > 0.5 * expected_bias > 0.0  # genuinely biased

    # DIANA: g = h_mean + mean Q(grad - h_i) with h arbitrary -> unbiased
    h = jax.random.normal(jax.random.PRNGKey(5), (N, D)) * 0.1
    def diana_estimate(key):
        keys = jax.random.split(key, N)
        qs = jax.vmap(lambda k, v: comp(k, v))(keys, grads_new - h)
        return jnp.mean(h, 0) + jnp.mean(qs, 0)
    est_d = jnp.mean(jax.vmap(diana_estimate)(keys), axis=0)
    se = float(jnp.linalg.norm(est_d - true_grad))
    assert se < 0.1 * max(expected_bias, 1e-3) + 0.02  # unbiased within MC error


def test_marina_pl_linear_convergence():
    data, L, mu = make_quadratic(jax.random.PRNGKey(2), N, 12, kappa=8.0)
    comp = RandK(k=3)
    p = comp.default_p(12)
    gamma = marina_gamma_pl(L, comp.omega(12), p, N, mu)
    m = Marina(grad_fn=jax.grad(quadratic_loss), compressor=comp, gamma=gamma, p=p)
    x_star = quad_optimum(data)
    f_star = float(jnp.mean(jax.vmap(quadratic_loss, in_axes=(None, 0))(x_star, data)))

    st = m.init(jnp.ones((12,)), data)
    f0 = float(jnp.mean(jax.vmap(quadratic_loss, in_axes=(None, 0))(st.params, data)))
    st, _ = run(m, st, data, 600)
    fK = float(jnp.mean(jax.vmap(quadratic_loss, in_axes=(None, 0))(st.params, data)))
    # (1 - gamma*mu)^600 decay with slack
    assert fK - f_star < (f0 - f_star) * 0.05


def test_vr_marina_converges_with_minibatches(problem):
    data, L = problem
    comp = RandK(k=3)
    b_prime = 8
    p = min(comp.default_p(D), b_prime / (M + b_prime))
    calL = L  # minibatch smoothness bound (Asm 3.1: L_i <= max_j L_ij)
    gamma = vr_marina_gamma(L, calL, comp.omega(D), p, N, b_prime)
    vr = VRMarina(
        full_grad_fn=jax.grad(nonconvex_binclass_loss),
        mb_grad_fn=jax.grad(nonconvex_binclass_loss),
        compressor=comp,
        gamma=gamma,
        p=p,
    )
    st = vr.init(jnp.zeros((D,)), data)
    step = jax.jit(vr.step)
    for k in range(1500):
        key = jax.random.PRNGKey(k)
        mb = sample_minibatch(jax.random.fold_in(key, 1), data, b_prime)
        st, met = step(st, key, data, mb)
    assert global_grad_sqnorm(st.params, data) < 5e-3


def test_pp_marina_converges(problem):
    data, L = problem
    comp = RandK(k=3)
    r = 2
    p = comp.default_p(D) * r / N
    gamma = pp_marina_gamma(L, comp.omega(D), p, r)
    ppm = PPMarina(
        grad_fn=jax.grad(nonconvex_binclass_loss), compressor=comp, gamma=gamma, p=p, r=r
    )
    st = ppm.init(jnp.zeros((D,)), data)
    st, _ = run(ppm, st, data, 1200)
    assert global_grad_sqnorm(st.params, data) < 5e-3


def test_baselines_converge(problem):
    data, L = problem
    comp = RandK(k=3)
    omega = comp.omega(D)
    # DIANA
    from repro.core import diana_gamma
    dia = Diana(
        grad_fn=jax.grad(nonconvex_binclass_loss),
        compressor=comp,
        gamma=diana_gamma(L, omega, N),
        alpha=diana_alpha(omega),
        n=N,
    )
    st = dia.init(jnp.zeros((D,)))
    st, _ = run(dia, st, data, 1500)
    assert global_grad_sqnorm(st.params, data) < 5e-3
    # EC-SGD with TopK
    ec = ECSGD(
        grad_fn=jax.grad(nonconvex_binclass_loss),
        compressor=TopK(k=3),
        gamma=0.5 / L,
        n=N,
    )
    st = ec.init(jnp.zeros((D,)))
    st, _ = run(ec, st, data, 800)
    assert global_grad_sqnorm(st.params, data) < 5e-3
    # DCGD (QSGD-style)
    dc = DCGD(
        grad_fn=jax.grad(nonconvex_binclass_loss),
        compressor=RandK(k=8),
        gamma=0.3 / (L * (1 + comp.omega(D) / N)),
        n=N,
    )
    st = dc.init(jnp.zeros((D,)))
    st, _ = run(dc, st, data, 800)
    assert global_grad_sqnorm(st.params, data) < 2e-2


def test_bits_ledger(problem):
    """Compressed rounds must report ζ_Q-proportional bits, dense rounds 32d."""
    data, L = problem
    comp = RandK(k=3)
    m = Marina(
        grad_fn=jax.grad(nonconvex_binclass_loss),
        compressor=comp,
        gamma=0.1,
        p=0.5,
    )
    st = m.init(jnp.zeros((D,)), data)
    step = jax.jit(m.step)
    seen = set()
    for k in range(30):
        st, met = step(st, jax.random.PRNGKey(k), data)
        if int(met.sync_round) == 1:
            assert float(met.bits_per_worker) == 32.0 * D
        else:
            assert float(met.bits_per_worker) == 64.0 * comp.k_for(D)
        seen.add(int(met.sync_round))
    assert seen == {0, 1}  # both round types exercised


def test_marina_state_is_jit_roundtrippable(problem):
    data, _ = problem
    comp = RandK(k=2)
    m = Marina(jax.grad(nonconvex_binclass_loss), comp, gamma=0.05, p=0.2)
    st = m.init(jnp.zeros((D,)), data)
    leaves, treedef = jax.tree.flatten(st)
    st2 = jax.tree.unflatten(treedef, leaves)
    _ = jax.jit(m.step)(st2, jax.random.PRNGKey(0), data)
