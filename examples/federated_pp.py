"""PP-MARINA example (deliverable b): federated partial participation.

Simulates a federated fleet where only r of n clients upload per round
(Alg. 4). Shows the Thm 4.1 trade: smaller r cuts per-round uplink and client
compute, at more rounds to the same accuracy — with total communication
decreasing, which is the paper's point for cross-device federated learning.

Run:  PYTHONPATH=src python examples/federated_pp.py
"""

import jax
import jax.numpy as jnp

from repro.core import PPMarina, RandK, pp_marina_gamma
from repro.core.problems import (
    BinClassData,
    binclass_full_grad,
    binclass_smoothness,
    make_synthetic_binclass,
    nonconvex_binclass_loss,
)

N, M, D = 20, 128, 60
TARGET = 3e-4


def grad_sqnorm(x, data):
    flat = BinClassData(a=data.a.reshape(-1, D), y=data.y.reshape(-1))
    return float(jnp.sum(binclass_full_grad(x, flat) ** 2))


def main():
    data = make_synthetic_binclass(jax.random.PRNGKey(1), N, M, D, heterogeneity=1.0)
    L = binclass_smoothness(data)
    comp = RandK(k=3)
    omega = comp.omega(D)
    grad_fn = jax.grad(nonconvex_binclass_loss)

    print(f"n={N} clients, d={D}, Rand3 (ω={omega:.0f})\n")
    print(f"{'r':>4} {'rounds':>7} {'total Mbits':>12} {'||∇f||²':>10}")
    for r in (20, 10, 4, 2):
        p = comp.default_p(D) * r / N
        gamma = pp_marina_gamma(L, omega, p, r)
        m = PPMarina(grad_fn, comp, gamma, p, r)
        st = m.init(jnp.zeros((D,)), data)
        step = jax.jit(m.step)
        bits = 0.0
        for k in range(8000):
            st, met = step(st, jax.random.PRNGKey(k), data)
            bits += float(met.bits_per_worker) * N  # total uplink
            if k % 100 == 99 and grad_sqnorm(st.params, data) < TARGET:
                break
        print(f"{r:>4} {k+1:>7} {bits/1e6:>12.2f} {grad_sqnorm(st.params, data):>10.2e}")


if __name__ == "__main__":
    main()
