"""PP-MARINA example (deliverable b): federated partial participation.

Simulates a federated fleet where only r of n clients upload per round
(Alg. 4), on Dirichlet(α) non-IID clients — the federated skew protocol of
DESIGN.md §6. Shows the Thm 4.1 trade: smaller r cuts per-round uplink and
client compute, at more rounds to the same accuracy — with total
communication roughly flat-to-decreasing, which is the paper's point for
cross-device federated learning. A final row runs the server-side carry
table (DESIGN.md §4.8): ONE backprop per sampled client instead of two —
half the client compute — at the cost of stale anchors (more rounds when r
is small, so it shines at moderate r/n).

Run:  PYTHONPATH=src python examples/federated_pp.py
"""

import jax
import jax.numpy as jnp

from repro.core import PPMarina, RandK, pp_marina_gamma
from repro.core.problems import (
    BinClassData,
    binclass_full_grad,
    binclass_smoothness,
    make_dirichlet_binclass,
    nonconvex_binclass_loss,
)

N, M, D = 20, 128, 60
TARGET = 3e-4


def grad_sqnorm(x, data):
    flat = BinClassData(a=data.a.reshape(-1, D), y=data.y.reshape(-1))
    return float(jnp.sum(binclass_full_grad(x, flat) ** 2))


def run(m, data, label):
    st = m.init(jnp.zeros((D,)), data)
    step = jax.jit(m.step)
    bits = oracle = 0.0
    for k in range(8000):
        st, met = step(st, jax.random.PRNGKey(k), data)
        bits += float(met.bits_per_worker) * N   # fleet-total uplink
        oracle += float(met.oracle_calls) * N    # fleet-total backprops
        if k % 100 == 99 and grad_sqnorm(st.params, data) < TARGET:
            break
    print(f"{label:>12} {k+1:>7} {bits/1e6:>12.2f} {oracle:>10.0f} "
          f"{grad_sqnorm(st.params, data):>10.2e}")


def main():
    data = make_dirichlet_binclass(jax.random.PRNGKey(1), N, M, D, alpha=0.3)
    L = binclass_smoothness(data)
    comp = RandK(k=3)
    omega = comp.omega(D)
    grad_fn = jax.grad(nonconvex_binclass_loss)

    print(f"n={N} Dir(0.3) clients, d={D}, Rand3 (ω={omega:.0f}), "
          "without-replacement cohorts\n")
    print(f"{'variant':>12} {'rounds':>7} {'total Mbits':>12} "
          f"{'backprops':>10} {'||∇f||²':>10}")
    for r in (20, 10, 4, 2):
        p = comp.default_p(D) * r / N
        gamma = pp_marina_gamma(L, omega, p, r)
        run(PPMarina(grad_fn, comp, gamma, p, r, replace=False), data,
            f"r={r}")
    # the §4.8 server-side carry table at moderate r: one backprop per
    # sampled client (half the oracle column) against slightly stale anchors
    r = 10
    p = comp.default_p(D) * r / N
    run(PPMarina(grad_fn, comp, pp_marina_gamma(L, omega, p, r), p, r,
                 replace=False, carry=True), data, f"r={r}+carry")


if __name__ == "__main__":
    main()
