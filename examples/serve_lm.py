"""Serving example (deliverable b): batched prefill + decode with every cache
flavour — full KV, sliding-window ring, recurrent state, MLA latent cache.

Picks a reduced assigned architecture (selectable with --arch), prefill a
batch of prompts, then decodes tokens greedily, printing throughput.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch gemma3-27b
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import PUBLIC_TO_MODULE, get_arch
from repro.models import decode_step, init_params, prefill, reduced


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-27b", choices=sorted(PUBLIC_TO_MODULE))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    cfg = reduced(arch.model, layers=2, d_model=128)
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)

    B, P, G = args.batch, args.prompt_len, args.gen
    total = P + G
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab_size)
    prefix = (
        jax.random.normal(key, (B, 8, cfg.d_model)) * 0.02
        if arch.prefix_len
        else None
    )

    print(f"arch={args.arch} (reduced) | batch={B} prompt={P} gen={G}")
    t0 = time.time()
    pre = jax.jit(lambda p, t, pe: prefill(p, cfg, t, pe, max_len=total + 8))
    logits, cache = pre(params, prompts, prefix)
    logits.block_until_ready()
    print(f"prefill: {time.time()-t0:.2f}s ({B*P} tokens)")

    dec = jax.jit(lambda p, c, t, pos: decode_step(p, cfg, c, t, pos))
    tok = jnp.argmax(logits, axis=-1)
    out = [tok]
    off = 0 if prefix is None else prefix.shape[1]
    t0 = time.time()
    for i in range(G - 1):
        logits, cache = dec(params, cache, tok, off + P + i)
        tok = jnp.argmax(logits, axis=-1)
        out.append(tok)
    jax.block_until_ready(out[-1])
    dt = time.time() - t0
    gen = jnp.stack(out, axis=1)
    print(f"decode: {G-1} steps × {B} seqs in {dt:.2f}s "
          f"({(G-1)*B/dt:.1f} tok/s)")
    print("sample continuation ids:", gen[0, :12].tolist())
    assert bool(jnp.all((gen >= 0) & (gen < cfg.vocab_size)))
    print("OK")


if __name__ == "__main__":
    main()
