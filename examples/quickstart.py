"""Quickstart: MARINA vs DIANA vs GD on the paper's §5.1 experiment.

Reproduces the qualitative claim of Fig. 1: to reach the same gradient-norm
target, MARINA needs far fewer transmitted bits than DIANA (and than
uncompressed GD), on the non-convex binary classification loss (eq. 11) with
heterogeneous workers and theoretical stepsizes.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import (
    Diana,
    Marina,
    RandK,
    diana_alpha,
    diana_gamma,
    make_gd,
    marina_gamma,
)
from repro.core.problems import (
    BinClassData,
    binclass_full_grad,
    binclass_smoothness,
    make_synthetic_binclass,
    nonconvex_binclass_loss,
)

N_WORKERS, M, D = 10, 256, 100
TARGET = 1e-4  # ||grad f||^2 target


def grad_sqnorm(x, data):
    flat = BinClassData(a=data.a.reshape(-1, D), y=data.y.reshape(-1))
    return float(jnp.sum(binclass_full_grad(x, flat) ** 2))


def run(name, method, state, data, needs_batches=True, max_steps=3000):
    step = jax.jit(method.step)
    bits = 0.0
    for k in range(max_steps):
        state, met = step(state, jax.random.PRNGKey(k), data)
        bits += float(met.bits_per_worker)
        if k % 50 == 0 and grad_sqnorm(state.params, data) < TARGET:
            break
    gn = grad_sqnorm(state.params, data)
    print(
        f"{name:>10}: steps={k+1:5d}  bits/worker={bits/1e6:9.3f} Mb  "
        f"final ||∇f||² = {gn:.2e}"
    )
    return bits, k + 1


def main():
    data = make_synthetic_binclass(jax.random.PRNGKey(0), N_WORKERS, M, D)
    L = binclass_smoothness(data)
    grad_fn = jax.grad(nonconvex_binclass_loss)
    x0 = jnp.zeros((D,))
    comp = RandK(k=5)  # Rand5, as in Fig. 1's K ∈ {1,5,10}
    omega = comp.omega(D)
    p = comp.default_p(D)

    print(f"n={N_WORKERS} workers, d={D}, RandK K=5 (ω={omega:.0f}), L={L:.3f}\n")

    # GD (dense communication)
    gd = make_gd(grad_fn, gamma=1.0 / L)
    run("GD", gd, gd.init(x0, data), data)

    # MARINA, theoretical stepsize (Thm 2.1)
    m = Marina(grad_fn, comp, marina_gamma(L, omega, p, N_WORKERS), p)
    run("MARINA", m, m.init(x0, data), data)

    # DIANA, theoretical stepsize
    dia = Diana(
        grad_fn, comp, diana_gamma(L, omega, N_WORKERS),
        diana_alpha(omega), N_WORKERS,
    )
    run("DIANA", dia, dia.init(x0), data)


if __name__ == "__main__":
    main()
