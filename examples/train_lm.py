"""End-to-end driver (deliverable b): distributed LM training with VR-MARINA.

Trains a transformer LM on the synthetic heterogeneous token pipeline with
compressed communication, logging loss vs *bits uplinked per worker* — the
paper's Fig. 2 axes, with ResNet18/CIFAR100 replaced by the modern equivalent
workload (DESIGN.md §6).

Default config is a ~100M-parameter model (for real hardware / the mesh
launcher). ``--smoke`` runs a ~5M-parameter variant for a few dozen steps so
the driver completes on this CPU container.

Run:  PYTHONPATH=src python examples/train_lm.py --smoke
"""

import argparse

import jax
import jax.numpy as jnp

from repro.models import init_params, param_count
from repro.models.config import ModelConfig, dense_stack
from repro.train import TrainConfig, Trainer


def model_100m() -> ModelConfig:
    return ModelConfig(
        name="lm-100m",
        arch_type="dense",
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        d_ff=3072,
        vocab_size=32768,
        segments=dense_stack(12),
    )


def model_smoke() -> ModelConfig:
    return ModelConfig(
        name="lm-smoke",
        arch_type="dense",
        d_model=160,
        num_heads=4,
        num_kv_heads=2,
        d_ff=512,
        vocab_size=2048,
        segments=dense_stack(3),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--method", default="vr_marina")
    ap.add_argument(
        "--compressor", default="randk",
        help="randk (per-leaf tree path), block_randk (fused flat engine), "
        "permk (correlated Perm-K: disjoint d/n shards, γ = 1/L theory), "
        "block_qsgd / block_natural (packed quantization wire: 4-bit/int8 "
        "levels + per-block norms, fused dequantize-and-mean)",
    )
    ap.add_argument("--qsgd-s", type=int, default=7,
                    help="quantization levels for block_qsgd (s ≤ 7 ships "
                    "the 4-bit nibble wire)")
    ap.add_argument("--k-frac", type=float, default=0.02)
    ap.add_argument("--gamma", type=float, default=0.25)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = model_smoke() if args.smoke else model_100m()
    steps = args.steps or (30 if args.smoke else 300)
    # block_randk's budget is kb coords per 1024-block (kb/1024 ≈ k_frac);
    # permk's budget is fixed by the partition (d/n per worker) and its
    # collection size is inferred from n_workers by the trainer.
    if args.compressor in ("block_randk", "flat_randk"):
        comp_kwargs = {"kb": max(1, round(args.k_frac * 1024))}
    elif args.compressor in ("permk", "perm_k"):
        comp_kwargs = {}
    elif args.compressor in ("block_qsgd", "flat_qsgd"):
        comp_kwargs = {"s": args.qsgd_s}
    elif args.compressor in ("block_natural", "flat_natural", "natural"):
        comp_kwargs = {}
    else:
        comp_kwargs = {"k": args.k_frac}
    tcfg = TrainConfig(
        method=args.method,
        compressor=args.compressor,
        comp_kwargs=comp_kwargs,
        gamma=args.gamma,
        n_workers=4,
        batch_per_worker=8 if args.smoke else 16,
        mb_per_worker=4 if args.smoke else 8,
        steps=steps,
        log_every=max(1, steps // 10),
        ckpt_dir=args.ckpt_dir,
        ckpt_every=max(1, steps // 3) if args.ckpt_dir else 0,
    )

    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"model={cfg.name} params={param_count(params):,} method={tcfg.method}")
    trainer = Trainer(cfg, tcfg, params)
    print(f"compressor ζ/d ≈ {args.k_frac}, p = {trainer.p:.4f}\n")

    state, hist = trainer.run()
    print(f"\n{'step':>6} {'loss':>8} {'||g||':>10} {'Mbits/worker':>13}")
    for s, l, g, b in zip(hist.step, hist.loss, hist.grad_est_norm, hist.bits_cum):
        print(f"{s:>6} {l:>8.4f} {g:>10.4f} {b/1e6:>13.2f}")

    assert hist.loss[-1] < hist.loss[0], "training must reduce loss"
    print("\nOK: loss decreased with compressed communication.")


if __name__ == "__main__":
    main()
