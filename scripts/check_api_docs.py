"""API-docs CI: import the public surface and fail on missing docstrings.

The checked surface is the doc contract of DESIGN.md/README.md:

* every name in ``repro.core.__all__`` (compressors, optimizers, engine,
  stepsizes) and ``repro.data.__all__``,
* the public methods of :class:`repro.core.FlatEngine`,
* the ``repro.launch.distributed`` builders and PP schedule,
* the ``repro.launch.topology`` fabric surface (meshes, tiers, bring-up)
  and the public methods of :class:`repro.launch.transport.Transport`,
* the experiment-problem constructors in ``repro.core.problems``,
* the wire-accounting formulas in ``repro.core.wire`` and the
  :class:`repro.core.wire.TierLedger` methods.

Every symbol must carry a non-empty ``__doc__`` (one-line summary + paper-
equation reference where applicable). Run: PYTHONPATH=src python
scripts/check_api_docs.py
"""

from __future__ import annotations

import inspect
import sys


def _missing_doc(obj) -> bool:
    doc = inspect.getdoc(obj)
    if doc:
        return False
    # dataclasses inherit nothing useful; plain data attributes are exempt
    return callable(obj) or inspect.isclass(obj)


def main():
    import repro.core as core
    import repro.data as data
    from repro.core import FlatEngine, problems, wire
    from repro.launch import distributed, topology, transport

    failures = []

    for mod in (core, data):
        for name in mod.__all__:
            obj = getattr(mod, name)
            if _missing_doc(obj):
                failures.append(f"{mod.__name__}.{name}")

    for cls, qual in (
        (FlatEngine, "repro.core.FlatEngine"),
        (transport.Transport, "repro.launch.transport.Transport"),
        (wire.TierLedger, "repro.core.wire.TierLedger"),
    ):
        for name, member in inspect.getmembers(cls):
            if name.startswith("_") or not callable(member):
                continue
            if not inspect.getdoc(member):
                failures.append(f"{qual}.{name}")

    for mod, names in (
        (distributed, ("build_train_steps", "build_serve_steps",
                       "pp_cohort_schedule", "StepBundle")),
        (topology, ("Topology", "LinkSpec", "detect_topology",
                    "production_topology", "initialize_multiprocess",
                    "spawn_local_cluster", "make_production_mesh",
                    "make_test_mesh", "make_federated_mesh",
                    "worker_axis_names", "num_workers",
                    "cohort_group_size")),
        (transport, ("Transport", "make_transport")),
        (problems, ("nonconvex_binclass_loss", "make_synthetic_binclass",
                    "make_dirichlet_binclass", "make_shifted_quadratics",
                    "gradient_heterogeneity", "quadratic_loss",
                    "make_quadratic", "quad_optimum", "sample_minibatch",
                    "binclass_smoothness")),
        (wire, ("qsgd_level_bits", "dense_f32_bits", "seeded_randk_bits",
                "permk_bits", "block_qsgd_bits", "block_natural_bits",
                "randk_qsgd_bits", "qsgd_global_bits", "natural_tree_bits",
                "correlated_q_bits", "pp_uplink_total_bits",
                "pp_sync_total_bits", "pp_expected_round_bits",
                "downlink_dense_bits", "round_total_bits")),
    ):
        for name in names:
            obj = getattr(mod, name)
            if _missing_doc(obj):
                failures.append(f"{mod.__name__}.{name}")

    if failures:
        print("MISSING DOCSTRINGS:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        sys.exit(1)
    print(
        "api docs OK (core/data exports, FlatEngine, launch "
        "topology/transport/assembly, problems, wire)"
    )


if __name__ == "__main__":
    main()
