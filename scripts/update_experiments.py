"""Render the §Roofline table in EXPERIMENTS.md from experiments/dryrun/*.json.

Usage: python scripts/update_experiments.py
"""

import glob
import json
import os
import re

ROOT = os.path.join(os.path.dirname(__file__), "..")
DRY = os.path.join(ROOT, "experiments", "dryrun")
EXP = os.path.join(ROOT, "EXPERIMENTS.md")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "deepseek-v3-671b", "qwen1.5-0.5b", "xlstm-350m", "recurrentgemma-2b",
    "llama4-scout-17b-a16e", "musicgen-medium", "qwen3-32b", "internvl2-1b",
    "deepseek-coder-33b", "gemma3-27b",
]


def fmt_bytes(b):
    if b is None:
        return "—"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def build_table():
    rows = []
    for f in glob.glob(os.path.join(DRY, "*.json")):
        with open(f) as fh:
            r = json.load(fh)
        rows.append(r)

    def key(r):
        a = ARCH_ORDER.index(r["arch"]) if r["arch"] in ARCH_ORDER else 99
        s = SHAPE_ORDER.index(r["shape"]) if r["shape"] in SHAPE_ORDER else 99
        return (a, s, r["mesh"])

    rows.sort(key=key)
    out = [
        "| arch | shape | mesh | step | comp_ms (analytic/HLO) | mem_ms | coll_ms | dominant | useful | HBM/dev | note |",
        "|---|---|---|---|---:|---:|---:|---|---:|---:|---|",
    ]
    n_ok = n_fail = 0
    for r in rows:
        for sname in ("sync_step", "compressed_step", "train_step",
                      "prefill_step", "decode_step"):
            s = r["steps"].get(sname)
            if s is None:
                continue
            if not s.get("ok"):
                n_fail += 1
                out.append(
                    f"| {r['arch']} | {r['shape']} | {r['mesh']} | {sname} "
                    f"| — | — | — | FAIL | — | — | {s.get('error','')[:60]} |"
                )
                continue
            n_ok += 1
            ma = s.get("memory_analysis", {})
            hbm = None
            if ma:
                hbm = (
                    ma.get("argument_size_in_bytes", 0)
                    + ma.get("output_size_in_bytes", 0)
                    + ma.get("temp_size_in_bytes", 0)
                    - ma.get("alias_size_in_bytes", 0)
                )
            ur = s.get("useful_ratio")
            note = ""
            if hbm and hbm > 16e9:
                note = "exceeds 16GB v5e HBM"
            # analytic compute term (recomputed for older JSONs)
            ana = s.get("analytic_compute_s")
            if ana is None:
                mft = s.get("model_flops_total") or 0.0
                ana = mft / s.get("n_devices", r["n_devices"]) / 197e12
            dom = s["dominant"]
            if max(ana, s["compute_s"]) >= max(s["memory_s"], s["collective_s"]):
                dom = "compute"
            out.append(
                "| {a} | {sh} | {m} | {st} | {an:.1f}/{c:.1f} | {me:.1f} | {co:.1f} "
                "| {dom} | {u} | {h} | {note} |".format(
                    a=r["arch"], sh=r["shape"], m=r["mesh"], st=sname,
                    an=ana * 1e3, c=s["compute_s"] * 1e3, me=s["memory_s"] * 1e3,
                    co=s["collective_s"] * 1e3, dom=dom,
                    u=f"{ur:.2f}" if ur else "—", h=fmt_bytes(hbm), note=note,
                )
            )
    out.append("")
    out.append(f"({n_ok} step-lowerings ok, {n_fail} failed; "
               f"{len(rows)} (arch × shape × mesh) combinations recorded)")
    return "\n".join(out)


def main():
    table = build_table()
    with open(EXP) as f:
        text = f.read()
    marker = "<!-- ROOFLINE_TABLE -->"
    pattern = re.compile(
        re.escape(marker) + r".*?(?=\n## |\Z)", re.DOTALL
    )
    replacement = marker + "\n\n" + table + "\n"
    text = pattern.sub(replacement.replace("\\", "\\\\"), text, count=1)
    with open(EXP, "w") as f:
        f.write(text)
    print(table[-400:])
    print("updated EXPERIMENTS.md")


if __name__ == "__main__":
    main()
