"""Render the §Perf hillclimbing log in EXPERIMENTS.md from
experiments/perf/*.json (+ baselines in experiments/dryrun/), and the
compression-engine trajectory from BENCH_compression.json
(written by `python -m benchmarks.run --only compression`).

Usage: python scripts/update_perf.py
"""

import glob
import json
import os
import re

ROOT = os.path.join(os.path.dirname(__file__), "..")
PERF = os.path.join(ROOT, "experiments", "perf")
DRY = os.path.join(ROOT, "experiments", "dryrun")
EXP = os.path.join(ROOT, "EXPERIMENTS.md")
BENCH_COMPRESSION = os.path.join(ROOT, "BENCH_compression.json")
BENCH_ROUNDSTEP = os.path.join(ROOT, "BENCH_roundstep.json")
BENCH_SERVE = os.path.join(ROOT, "BENCH_serve.json")

EXP_SKELETON = """# EXPERIMENTS

## Compression engine

<!-- COMPRESSION_BENCH -->

## Round pipeline

<!-- ROUNDSTEP_BENCH -->

## Perf log

<!-- PERF_LOG -->
"""

# hypothesis text per variant (mirrors repro/launch/perf.py VARIANTS)
HYPOTHESES = {
    "shared_mask": "shared RandK mask ⇒ worker-mean before the collective: "
    "K-value psum replaces the n·K payload all-gather ⇒ collective term ↓ "
    "(theory cost: ω instead of ω/√n in Thm 2.1).",
    "packed_payload": "bf16 values + int16 indices on the wire (8→4 B/coord; "
    "int32 indices when L > 32767, 8→6 B/coord) ⇒ payload collective bytes "
    "↓ ~2× with no algorithmic change.",
    "shared_and_packed": "both payload optimizations composed.",
    "permk_payload": "correlated Perm-K (Szlendak et al. 2021): shared "
    "permutation ⇒ disjoint d/n shards per worker, values-only exchange (no "
    "index payload — the permutation regenerates from the replicated round "
    "key), scatter-free assembly, and (A,B)=(1,1) admits the GD stepsize "
    "γ = 1/L.",
    "permk_packed": "Perm-K shards + bf16 values: 2 B/coord on the wire vs "
    "the independent-mask packed path's 4 B/coord.",
    "qsgd_payload": "packed quantization wire (DESIGN.md §4.6): dense "
    "s-level QSGD against per-row ℓ2 norms — the payload collective carries "
    "int8 levels + f32 norms (1 B/coord, 4× fewer bytes than the f32 diffs) "
    "while the dense diffs stay worker-local (staged constraints).",
    "qsgd4_packed": "4-bit wire: s = 7 levels fit signed nibbles, packed "
    "eight-per-uint32 lane word — 0.5 B/coord on the collective (8× fewer "
    "bytes than an f32 dense wire) at ω = min(L/49, √L/7). NOTE the "
    "baseline compressed round is K-sparse RandK (ζ = d/128), so a dense "
    "quantizer MUST grow this step's collective ≈ d/(128·8)-fold — the "
    "expected verdict here is REFUTED; the packed wire's win over the f32 "
    "representation of the same quantizer is recorded in bench_compression "
    "(7.9×) and the dense wire is for DIANA/DCGD-style dense-method "
    "workloads, not a RandK replacement.",
    "grad_carry": "gradient-carry rounds: the carried h_i^k = ∇f_i(x^k) "
    "replaces the second vmapped backprop of every compressed round ⇒ "
    "compute term of compressed_step ↓ ~2× (one grad sweep), at the memory "
    "cost of one worker-stacked gradient tree in the carry.",
    "downlink_qsgd": "compressed downlink: the server broadcasts "
    "Q_down(g^{k+1} − g^k) (per-row s=7 QSGD of the aggregated delta) "
    "instead of the dense f32 estimator ⇒ the previously-uncounted 32d "
    "broadcast shrinks to ~4 bits/coord; compute adds one d-sweep "
    "quantize/decode.",
    "carry_down_qsgd": "grad-carry + compressed downlink composed: one "
    "backprop per round and both wire directions compressed.",
    "flat_sync": "sync rounds exchange ONE packed (nblk, B) buffer (a "
    "single worker-axis psum) instead of one collective per leaf. Expected "
    "REFUTED on tensor/FSDP-sharded params: GSPMD must all-gather the dense "
    "grads to assemble the buffer (involuntary full remat) — which is why "
    "the packed exchange only auto-enables on worker-pure/replicated "
    "meshes.",
    "tree_sync": "negative control: per-leaf dense sync exchange forced on "
    "a mesh where the packed flat-psum exchange is the auto default.",
    "no_remat": "dropping rematerialization ⇒ compute term ↓ (no recompute) "
    "at the cost of activation memory ↑.",
    "replicate_params": "small model: abandon tensor parallelism; model axis "
    "becomes within-worker data parallelism ⇒ the per-timestep reshard "
    "collectives of the recurrent scan disappear; only one dense grad "
    "all-reduce remains.",
    "chunk_2048": "wider attention chunks ⇒ fewer online-softmax merge passes "
    "and better MXU utilization; memory term ↑ slightly.",
    "chunk_512": "narrower chunks ⇒ smaller live set, memory term ↓, more "
    "merge overhead.",
    "cap_1.0": "lower MoE capacity factor ⇒ dispatch buffers and expert "
    "GEMM flops ↓ proportionally (more drops).",
    "workers_pod_data": "more MARINA workers (thinner model shards) ⇒ "
    "compression collective n↑ but per-worker gradient cheaper.",
    "f32_params": "fp32 parameters ⇒ memory/collective terms ×2 (negative "
    "control for the accounting).",
    "staged_payload": "the v1 baseline's compressed-round collective term is "
    "not the payload: GSPMD replicates the *dense gradient diffs* to satisfy "
    "the replicated-payload layout (e.g. 43 TB wire at 671B). Pinning the "
    "gather output to the worker-sharded layout first, then replicating only "
    "the K-sized payload, restores the paper's ζ_Q-scale collective.",
    "staged_shared": "staged constraints + shared mask: worker-mean psum of "
    "the ζ-sized payload, fully sharded end to end (MARINA-SM — the scalable "
    "giant-model schedule).",
    "unstaged_payload": "negative control for staged_payload.",
    "last_logits": "prefill unembeds only the final position: the (B,S,V) "
    "logits tensor (e.g. 32×32k×152k) disappears from the serve step.",
    "paged_decode": "paged KV decode (DESIGN.md §8): the pool holds "
    "Σ ceil(len_i/P) pages instead of n_slots × max_len dense rows, so the "
    "memory-bound decode step streams only the occupied pages — the modeled "
    "pool here is sized at 50% mean occupancy, halving the decode step's "
    "HBM traffic (and live memory) vs the dense-cache decode_32k baseline; "
    "roofline/analysis.py::decode_bandwidth_bound_s prices the bound.",
}


def load(path):
    with open(path) as f:
        return json.load(f)


def fmt_step(s):
    return (
        f"comp {s['compute_s']*1e3:.1f} / mem {s['memory_s']*1e3:.1f} / "
        f"coll {s['collective_s']*1e3:.1f} ms (dom {s['dominant']})"
    )


def render_compression_bench():
    """BENCH_compression.json → markdown table (per-leaf vs flat-fused)."""
    if not os.path.exists(BENCH_COMPRESSION):
        return "(no compression benchmark recorded — run `python -m benchmarks.run --only compression`)"
    r = load(BENCH_COMPRESSION)
    quick = " — ⚠ QUICK MODE (noisy, re-run without --quick)" if r.get("quick") else ""
    lines = [
        f"Fused flat-buffer engine vs per-leaf tree path "
        f"(B={r['block']}, kb={r['kb']}, backend={r['backend']}, "
        f"reps={r.get('reps', '?')}){quick}:",
        "",
        "| d | n | per-leaf µs | flat-fused µs | speedup | agg floats (tree → flat) |",
        "|---|---|---|---|---|---|",
    ]
    for e in r["entries"]:
        lines.append(
            f"| {e['d']:.0e} | {e['n']} | {e['per_leaf_us']:.0f} "
            f"| {e['flat_fused_us']:.0f} | **{e['speedup']:.1f}×** "
            f"| {e['per_leaf_agg_floats']:.1e} → {e['flat_agg_floats']:.1e} |"
        )
    lines.append("")
    lines.append(
        "Aggregation-path peak memory no longer scales with n·d: the flat "
        "path holds n ζ-sized payloads plus one dense accumulator."
    )
    if any("permk_us" in e for e in r["entries"]):
        lines += [
            "",
            "### Disjoint-support aggregation (Perm-K) vs n·K all-gather",
            "",
            "Matched per-worker coordinate budget K_w = padded/n. Payload "
            "bytes use the production wire dtypes: the independent-mask "
            "all-gather moves bf16 values + int16 indices (4 B/coord) for "
            "all n workers; the Perm-K exchange is an exact all-to-all of "
            "d/n shards — bf16 values only + one shared 4-byte seed (the "
            "partition IS the index). Wall-clock compares the fused rounds "
            "(scatter-accumulate vs scatter-free inverse-perm assembly).",
            "",
            "| d | n | K_w/worker | all-gather bytes | disjoint bytes | "
            "bytes ↓ | all-gather µs | disjoint µs |",
            "|---|---|---|---|---|---|---|---|",
        ]
        for e in r["entries"]:
            if "permk_us" not in e:
                continue
            ratio = e["allgather_payload_bytes"] / e["disjoint_payload_bytes"]
            lines.append(
                f"| {e['d']:.0e} | {e['n']} | {e['matched_coords_per_worker']} "
                f"| {e['allgather_payload_bytes']:,} "
                f"| {e['disjoint_payload_bytes']:,} | **{ratio:.2f}×** "
                f"| {e['allgather_us']:.0f} | {e['permk_us']:.0f} |"
            )
        lines += [
            "",
            "Perm-K additionally runs MARINA at the GD stepsize γ = 1/L "
            "((A, B) = (1, 1) — see core/stepsize.py::marina_gamma_permk), "
            "which no independent ω-compressor admits.",
        ]
    if any("qsgd_us" in e for e in r["entries"]):
        s = r.get("qsgd_s", "?")
        lines += [
            "",
            "### Packed quantization wire (block-QSGD / RandK∘QSGD)",
            "",
            f"Same ω-quantizer, two wire representations (s = {s}, 4-bit "
            "nibble levels + per-block f32 norms — DESIGN.md §4.6): the "
            "packed wire vs the f32 wire a quantized round crossed before "
            "this engine existed — launch/distributed.py had no quantized "
            "payload collective (dense f32 diffs, 4 B/coord) and the flat "
            "engine no quantized sampler (f32 values for the composition). "
            "For calibration: the per-leaf *simulation* arrays were already "
            "int8+norm (the ledger booked ~4 bits/coord), so against that "
            "in-memory representation the nibble win is 2×, not 7.9×. "
            "Wall-clock compares the fused packed round against the "
            "per-leaf tree path (dense QSGD) and against the flat-fused "
            "RandK round it rides on (the composition quantizes only the K "
            "sampled values).",
            "",
            "| d | n | round | wire bytes (packed) | wire bytes (f32) | "
            "bytes ↓ | fused µs | baseline µs |",
            "|---|---|---|---|---|---|---|---|",
        ]
        for e in r["entries"]:
            if "qsgd_us" not in e:
                continue
            rq = e["qsgd_f32_payload_bytes"] / e["qsgd_packed_payload_bytes"]
            rr = (
                e["randk_qsgd_f32_payload_bytes"]
                / e["randk_qsgd_packed_payload_bytes"]
            )
            lines.append(
                f"| {e['d']:.0e} | {e['n']} | dense qsgd "
                f"| {e['qsgd_packed_payload_bytes']:,.0f} "
                f"| {e['qsgd_f32_payload_bytes']:,.0f} | **{rq:.1f}×** "
                f"| {e['qsgd_us']:.0f} | {e['per_leaf_qsgd_us']:.0f} "
                "(per-leaf) |"
            )
            lines.append(
                f"| {e['d']:.0e} | {e['n']} | randk∘qsgd "
                f"| {e['randk_qsgd_packed_payload_bytes']:,.0f} "
                f"| {e['randk_qsgd_f32_payload_bytes']:,.0f} | **{rr:.1f}×** "
                f"| {e['randk_qsgd_us']:.0f} | {e['flat_fused_us']:.0f} "
                "(flat randk) |"
            )
        lines += [
            "",
            "Aggregation of the dense quantized rounds runs through the "
            "fused dequantize-and-mean kernel: int8 input bandwidth, one "
            "(nblk, B) f32 accumulator, no (n, d) dequantized trees. "
            "CPU-sim caveat: the dense quantize pass is murmur-RNG-bound in "
            "the jnp oracle (the per-leaf baseline rides XLA's native "
            "threefry), so its wall-clock win is on the wire and in "
            "aggregation memory, not the CPU dither; on TPU the dither is "
            "one on-chip VPU pass. The composition row is the round-time "
            "criterion: it rides the identical gather/scatter as flat RandK "
            "and lands at parity (±5% at d = 1e6).",
        ]
    return "\n".join(lines)


def render_roundstep_bench():
    """BENCH_roundstep.json → markdown table (end-to-end train-step wall
    clock + the up+down total-bytes column)."""
    if not os.path.exists(BENCH_ROUNDSTEP):
        return ("(no round-step benchmark recorded — run "
                "`python -m benchmarks.run --only roundstep`)")
    r = load(BENCH_ROUNDSTEP)
    quick = " — ⚠ QUICK MODE (noisy, re-run without --quick)" if r.get("quick") else ""
    lines = [
        f"End-to-end MARINA train-step wall clock (jit-compiled, interleaved "
        f"min-of-trials; B={r['block']}, kb={r['kb']}, downlink s={r['down_s']}, "
        f"backend={r['backend']}, reps={r.get('reps', '?')}){quick}. "
        "`two-backprop` is the pre-carry compressed round (flat-fused RandK "
        "uplink, dequant-mean + two tree.map passes); `carry+epilogue` runs "
        "ONE backprop against the carried h_i^k and finishes in the fused "
        "(nblk, B)-sweep epilogue kernel; `+downlink` additionally broadcasts "
        "Q_down(g^{k+1} − g^k) as 4-bit block QSGD. The total-wire column "
        "counts BOTH directions per worker per compressed round — the dense "
        "f32 downlink the ledger used to ignore is what the compressed "
        "downlink removes.",
        "",
        "| d | n | sync µs | two-backprop µs | carry+epilogue µs | speedup "
        "| +downlink µs | up+down KB (dense down) | up+down KB (Q_down) | "
        "wire ↓ |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for e in r["entries"]:
        lines.append(
            f"| {e['d']:.0e} | {e['n']} | {e['sync_us']:.0f} "
            f"| {e['two_backprop_us']:.0f} | {e['carry_fused_us']:.0f} "
            f"| **{e['carry_speedup']:.2f}×** | {e['carry_down_us']:.0f} "
            f"| {e['total_bits_baseline']/8/1024:,.1f} "
            f"| {e['total_bits_down_q']/8/1024:,.1f} "
            f"| **{e['wire_reduction']:.1f}×** |"
        )
    lines += [
        "",
        "Grad-carry trajectories are bit-exact against the two-backprop "
        "seed estimator (deterministic oracle; tests/test_roundstep.py), "
        "with the carried params leading by exactly one lookahead step. "
        "CI gates on the carry/sync ratio (scripts/check_roundstep.py): "
        "absolute µs are not comparable across runners, the within-run "
        "ratio is.",
    ]
    mp = r.get("multiproc")
    if mp:
        lines += ["", "### Multi-process smoke row (2-process local cluster)", ""]
        mpq = (" — ⚠ QUICK MODE (noisy, re-run without --quick)"
               if mp.get("quick") else "")
        lines += [
            "Same compressed grad-carry round (reduced-qwen, 4 global "
            "devices) through `jax.distributed` with gloo CPU collectives: "
            "2 processes × 2 devices (the worker axis crosses a real OS "
            "process boundary — the local cluster's simulated dcn) vs the "
            "historical 1-process fake-device mesh. Identical wire bits, "
            f"re-tiered by the transport ledger{mpq}:",
            "",
            "| layout | worker tier | compressed µs | up bits/worker (tier) |",
            "|---|---|---|---|",
        ]
        for label in ("2proc", "1proc"):
            e = mp.get(label)
            if not e:
                continue
            if not e.get("ok"):
                lines.append(f"| {label} | — | FAILED | — |")
                continue
            tier = e["worker_tier"]
            up = e.get("wire_by_tier", {}).get(tier, {}).get("up", 0.0)
            lines.append(
                f"| {e['n_processes']}×{e['n_devices']//e['n_processes']} dev "
                f"| {tier} | {e['compressed_us']:.0f} "
                f"| {up:,.0f} ({tier}) |"
            )
        if "cross_process_slowdown" in mp:
            lines += [
                "",
                f"Cross-process slowdown: "
                f"**{mp['cross_process_slowdown']:.2f}×** — the gloo hop is "
                "what the per-tier α–β roofline model prices and the "
                "compressed wires amortize (trajectory equality across "
                "layouts is asserted in tests/test_multiproc.py).",
            ]
    return "\n".join(lines)


def render_serve_bench():
    """BENCH_serve.json → markdown: continuous vs static tokens/s + latency
    percentiles on the mixed-length workload (DESIGN.md §8)."""
    if not os.path.exists(BENCH_SERVE):
        return ("(no serving benchmark recorded — run "
                "`python -m benchmarks.run --only serve`)")
    r = load(BENCH_SERVE)
    quick = " — ⚠ QUICK MODE (noisy, re-run without --quick)" if r.get("quick") else ""
    from collections import Counter
    wl = Counter(tuple(p) for p in r["workload"])
    wl_str = ", ".join(
        f"{c}× ({p}p+{g}g)" for (p, g), c in sorted(wl.items())
    )
    lines = [
        f"Continuous batching over the paged KV cache vs static batching "
        f"({r['arch']}, {r['n_requests']} requests, {r['slots']} slots, "
        f"page size {r['page_size']}, prefill chunk {r['chunk']}, "
        f"backend={r['backend']}){quick}. Workload (prompt+gen): {wl_str} — "
        "each group of 4 mixes one long generation with three short ones, "
        "the regime where static batching decodes at the pace of its longest "
        "member while the engine backfills freed slots from the admission "
        "queue. tokens/s counts useful tokens only; `q8` is the int8 "
        "quantized-page pool (same scheduler, ~4× smaller KV residency):",
        "",
        "| mode | tokens/s | vs static | first-token p50/p99 ms | "
        "completion p50/p99 ms | decode dispatches |",
        "|---|---|---|---|---|---|",
    ]
    for name, label in (
        ("continuous", "continuous (paged f32)"),
        ("continuous_q8", "continuous (paged int8)"),
        ("static", "static (dense cache)"),
    ):
        e = r.get(name)
        if not e:
            continue
        ratio = e["tokens_per_s"] * (
            1.0 / r["static"]["tokens_per_s"] if r.get("static") else 0.0
        )
        steps = e.get("decode_steps", "—")
        lines.append(
            f"| {label} | {e['tokens_per_s']:.1f} | **{ratio:.2f}×** "
            f"| {e['first_token_p50_ms']:.0f} / {e['first_token_p99_ms']:.0f} "
            f"| {e['completion_p50_ms']:.0f} / {e['completion_p99_ms']:.0f} "
            f"| {steps} |"
        )
    sp = r.get("shared_prefix")
    if sp:
        lines += [
            "",
            f"**Prefix sharing (COW pages):** {sp['n_requests']} requests "
            f"over {sp['n_prefixes']} shared {sp['prefix_len']}-token "
            f"prefixes — sharing on vs off on the same engine: "
            f"**{sp['shared_over_unshared']:.2f}×** tokens/s, "
            f"**{sp['prefill_token_reduction']:.2f}×** fewer prompt tokens "
            f"prefilled ({sp['unshared']['prefill_tokens']} → "
            f"{sp['shared']['prefill_tokens']}), "
            f"{sp['shared']['cow_splits']} copy-on-write page splits. "
            "Followers map the donor's cached prompt pages through the "
            "prefix index and split only the partial tail page on first "
            "write; logits stay bit-identical to independent runs "
            "(tests/test_serve.py).",
        ]
    pre = r.get("preemption")
    if pre:
        lines += [
            "",
            f"**Preemption (tight pool):** the same workload over "
            f"{pre['npage']} pages (~1.5 worst-case residents) — "
            f"{pre['preemptions']} preemptions, {pre['swapped_pages']} "
            f"pages swapped to host, all {pre['n_requests']} requests "
            f"completed at {pre['tokens_per_s']:.1f} tokens/s (roomy pool: "
            f"{pre['roomy_tokens_per_s']:.1f}). Victims are swapped out "
            "page-for-page and resumed by re-mapping; the soak test "
            "asserts preempted streams match unpreempted ones token for "
            "token (tests/test_serve_soak.py).",
        ]
    lines += [
        "",
        "Paged decode logits match the dense-cache reference to fp32 "
        "accumulation tolerance with identical greedy streams (bit-exact at "
        "the kernel level vs the jnp oracle); the int8 page error model is "
        "|x − x̂| ≤ max|x|/254 per KV row (tests/test_serve.py, DESIGN.md "
        "§8). CI gates on the within-run continuous/static ratio, the "
        "shared-prefix win (tokens/s OR prefill-token reduction), and the "
        "tight-pool preemption section (scripts/check_serve.py): absolute "
        "tokens/s are not comparable across runners, within-run ratios are.",
    ]
    return "\n".join(lines)


def render_pp_bench():
    """BENCH_pp.json → markdown: the loss-vs-bits budget table across
    Dirichlet-α heterogeneity + the mesh round-time r/n saving row."""
    path = os.path.join(ROOT, "BENCH_pp.json")
    if not os.path.exists(path):
        return ("(no federated PP benchmark recorded — run "
                "`python -m benchmarks.bench_pp`)")
    r = load(path)
    quick = " — ⚠ QUICK MODE (noisy, re-run without --quick)" if r.get("quick") else ""
    prob = r["problem"]
    methods = []
    for c in r["curves"]:
        if c["method"] not in methods:
            methods.append(c["method"])
    lines = [
        f"Dirichlet(α) non-IID eq.-(11) binclass, n = {prob['n_clients']} "
        f"clients × m = {prob['m_local']} samples, d = {prob['d']}, all "
        f"methods on the same {prob['compressor']} wire; PP cohorts sampled "
        f"{prob['scheme']} replacement{quick}. Cells are the best ‖∇f(x)‖² "
        "reached within each MATCHED fleet-uplink budget (the paper's "
        "Figs. 1–2 x-axis, booked by the wire.py ledger — `—` = the method "
        "never logged under that budget). Gradient-difference compression "
        "(MARINA/PP-MARINA) should widen its lead over direct compression "
        "(DIANA/DCGD) as α shrinks; PP-MARINA matches MARINA at a fraction "
        "of the budget by uploading only r of n clients.",
        "",
        "| α | budget (Mbit) | " + " | ".join(methods) + " |",
        "|---|---|" + "---|" * len(methods),
    ]
    for row in r["budget_table"]:
        for budget, cell in row["budgets"].items():
            vals = []
            best = min((v for v in cell.values() if v is not None),
                       default=None)
            for m in methods:
                v = cell.get(m)
                if v is None:
                    vals.append("—")
                else:
                    s = f"{v:.1e}"
                    vals.append(f"**{s}**" if v == best else s)
            lines.append(
                f"| {row['alpha']} | {budget} | " + " | ".join(vals) + " |"
            )
    rt = r.get("roundtime")
    if rt:
        lines += [
            "",
            f"**Mesh round time** (8 fake CPU devices, 4×2 mesh, reduced-qwen "
            f"d = {rt['d']:,}): cohort-mapped PP compressed round "
            f"(the r = {rt['r']} sampled clients' tokens respread over all "
            f"n = {rt['n']} shards — each shard backprops r/n of its "
            "full-round tokens) "
            f"{rt['pp_us']/1e3:.0f} ms vs full participation "
            f"{rt['full_us']/1e3:.0f} ms — **{rt['speedup']:.2f}× faster**, "
            f"with **{rt['wire_bits_full']/rt['wire_bits_pp']:.1f}× fewer "
            f"uplink bits** ({rt['wire_bits_pp']/8/1024:,.0f} KB vs "
            f"{rt['wire_bits_full']/8/1024:,.0f} KB per compressed round, "
            "wire.py accounting). Cohort compute was active "
            f"(`cohort_compute={rt['cohort_compute']}`).",
        ]
    lines += [
        "",
        "Curves (per-round cumulative bits + ‖∇f‖² + loss) are stored in "
        "`BENCH_pp.json`; the mesh PP round is trajectory-equal to the core "
        "`PPMarina` reference (tests/test_pp.py).",
    ]
    return "\n".join(lines)


def render_robust_bench():
    """BENCH_pp.json ``robust`` section → markdown: the attack × GAR ×
    fraction loss grid + the robust round-time row (DESIGN.md §4.9)."""
    path = os.path.join(ROOT, "BENCH_pp.json")
    if not os.path.exists(path):
        return ("(no robust benchmark recorded — run "
                "`python -m benchmarks.run --only robust`)")
    r = load(path).get("robust")
    if r is None:
        return ("(no robust benchmark recorded — run "
                "`python -m benchmarks.run --only robust`)")
    quick = " — ⚠ QUICK MODE (noisy, re-run without --quick)" if r.get("quick") else ""
    cells = r["cells"]
    gars = []
    for c in cells:
        if c["gar"] not in gars:
            gars.append(c["gar"])
    lines = [
        f"PP-MARINA under client attacks: n = {r['n']} clients, cohorts "
        f"r = {r['r']}, dense 4-bit QSGD wire ({r['compressor']}), "
        f"γ = {r['gamma']}, p = {r['p']}, heterogeneity = "
        f"{r['heterogeneity']}, attack scale = {r['scale']}, "
        f"{r['steps']} rounds{quick}. Cells are the final loss on the HONEST "
        "objective, with the ratio to the attack-free mean baseline "
        f"(free loss = {r['free_loss']:.4f}) — every payload cell books "
        "identical fleet uplink bits (matched budgets by construction; the "
        "`drop` row books fewer — the carry-substitution ledger counts only "
        "actual uploads). MARINA's recursion never forgets an accepted "
        "corruption, so the plain mean drifts persistently while the "
        "coordinate-wise GARs stay within the honest-spread trim bias.",
        "",
        "| attack | faulty frac | " + " | ".join(gars) + " | Mbits |",
        "|---|---|" + "---|" * (len(gars) + 1),
    ]
    seen = []
    for c in cells:
        k = (c["attack"], c["frac"])
        if k not in seen:
            seen.append(k)
    by = {(c["attack"], c["frac"], c["gar"]): c for c in cells}
    for attack, frac in seen:
        vals, mbits = [], None
        row_cells = [by.get((attack, frac, g)) for g in gars]
        finite = [c["final_loss"] for c in row_cells if c]
        best = min(finite) if finite else None
        for c in row_cells:
            if c is None:
                vals.append("—")
                continue
            mbits = c["mbits_up"]
            s = f"{c['final_loss']:.3f} ({c['loss_vs_free']:.2f}×)"
            vals.append(f"**{s}**" if c["final_loss"] == best and
                        len(finite) > 1 else s)
        lines.append(f"| {attack} | {frac:g} | " + " | ".join(vals) +
                     f" | {mbits:.2f} |")
    rt = r.get("roundtime")
    if rt:
        lines += [
            "",
            f"**Robust round time** (n = {rt['n']} worker rows, "
            f"d = {rt['d']:,}, backend = {rt['backend']}): fused robust "
            f"round {rt['round_trimmed']/1e3:.1f} ms (trimmed) / "
            f"{rt['round_median']/1e3:.1f} ms (median) vs fused mean round "
            f"{rt['round_mean']/1e3:.1f} ms — "
            f"**{rt['round_trimmed_over_mean']:.2f}× / "
            f"{rt['round_median_over_mean']:.2f}×** (CI gates ≤ 1.25×, "
            "scripts/check_robust.py). The isolated sync epilogue is "
            f"{rt['sync_trimmed_over_mean']:.2f}× the mean epilogue on this "
            "backend — recorded, not gated: the CPU ref pays a compute-bound "
            "compare-exchange network against a single memory-bound mean "
            "pass, whereas the TPU Pallas kernel's extra compares ride "
            "in-register on the same HBM traffic (the ~1.2× epilogue "
            "regime).",
        ]
    lines += [
        "",
        "Per-cell gradsq/bits live in `BENCH_pp.json` (`robust` section); "
        "fault semantics and GAR/wire compatibility are specified in "
        "DESIGN.md §4.9 and regression-tested in tests/test_robust.py.",
    ]
    return "\n".join(lines)


def render_async_bench():
    """BENCH_pp.json ``async`` section → markdown: the wall-clock-vs-straggler
    table (deadline cohorts vs synchronous full participation, DESIGN.md
    §4.10) + per-distribution speedup rows."""
    path = os.path.join(ROOT, "BENCH_pp.json")
    if not os.path.exists(path):
        return ("(no straggler benchmark recorded — run "
                "`python -m benchmarks.run --only async`)")
    r = load(path).get("async")
    if r is None:
        return ("(no straggler benchmark recorded — run "
                "`python -m benchmarks.run --only async`)")
    quick = " — ⚠ QUICK MODE (noisy, re-run without --quick)" if r.get("quick") else ""
    prob = r["problem"]
    variants = []
    for c in r["curves"]:
        if c["variant"] not in variants:
            variants.append(c["variant"])
    by = {(c["dist"], c["variant"]): c for c in r["curves"]}
    lines = [
        f"Deadline-cohort MARINA vs synchronous full participation under "
        f"simulated per-client compute-time distributions "
        f"(core/roundtime.py): n = {prob['n_clients']} clients × "
        f"m = {prob['m_local']} samples, d = {prob['d']}, "
        f"Dirichlet(α = {prob['alpha']}) heterogeneity, "
        f"{prob['compressor']} wire{quick}. `sync` waits for the slowest "
        "client every round; `deadline_q{q}` sets the server deadline at the "
        "q-quantile of the fleet round-time distribution and treats misses "
        "as PP non-participants via the carry table (Δ̂_i = 0, no h_i "
        "refresh, no bits booked); `_tau2` additionally accepts uploads up "
        "to τ_max = 2 rounds late as stale differences, with the γ rule "
        "degraded by observed staleness (core/stepsize.py::"
        "async_marina_gamma — heuristic, not a paper rate). Wall-clock is "
        "the roundtime model's simulated time to reach the MATCHED target "
        "loss (worst final loss across that distribution's variants):",
        "",
        "| arrival dist | target loss | " +
        " | ".join(f"{v} wall-s (rounds)" for v in variants) +
        " | best speedup |",
        "|---|---|" + "---|" * (len(variants) + 1),
    ]
    for row in r["wall_table"]:
        cells = []
        for v in variants:
            w, k = row["wall_s"].get(v), row["rounds"].get(v)
            cells.append("—" if w is None else f"{w:,.0f} ({k})")
        speed = {v: s for v, s in row["speedup_vs_sync"].items()
                 if v != "sync" and s is not None}
        if speed:
            bv = max(speed, key=speed.get)
            best = f"**{speed[bv]:.2f}×** ({bv})"
        else:
            best = "—"
        lines.append(
            f"| {row['dist']} | {row['target_loss']:.4f} | " +
            " | ".join(cells) + f" | {best} |"
        )
    arr = {(c["dist"], c["variant"]): c["arrived_frac"]
           for c in r["curves"]}
    frac_bits = ", ".join(
        f"{d}/{v} {f:.0%}" for (d, v), f in sorted(arr.items())
        if v != "sync"
    )
    lines += [
        "",
        f"Expected on-time arrival fractions (clients billed per round): "
        f"{frac_bits} — the ledger books only arrived uploads "
        f"(arrived·ζ_Q bits/round vs n·ζ_Q for sync), so the deadline "
        "variants also win the bits axis at these fractions.",
        "",
        "Deadline rounds are bit-identical to full participation when no "
        "client misses (p_miss = 0 gate, scripts/check_async.py), and a "
        "statically-slow client set is trajectory-equal to the same ids "
        "under FaultSpec drop (tests/test_async.py). Crash recovery on the "
        "real 2-process gloo cluster — a killed worker detected by "
        "heartbeat, the round completed by the surviving cohort, training "
        "resumed — is asserted trajectory-equal (rtol 1e-5) to the "
        "single-process deadline-miss reference in tests/test_multiproc.py.",
    ]
    return "\n".join(lines)


def _splice(text, marker, body):
    pattern = re.compile(re.escape(marker) + r".*?(?=\n## |\Z)", re.DOTALL)
    return pattern.sub(
        (marker + "\n\n" + body + "\n").replace("\\", "\\\\"), text, count=1
    )


def main():
    entries = []
    for f in sorted(glob.glob(os.path.join(PERF, "*.json"))):
        r = load(f)
        if r["variant"] == "baseline":
            continue
        base_perf = os.path.join(
            PERF, f"{r['arch']}__{r['shape']}__{r['mesh']}__baseline.json"
        )
        base_dry = os.path.join(
            DRY, f"{r['arch']}__{r['shape']}__{r['mesh']}.json"
        )
        base = None
        if os.path.exists(base_perf):
            base = load(base_perf)
        elif os.path.exists(base_dry):
            base = load(base_dry)
        lines = [
            f"### {r['arch']} × {r['shape']} × {r['mesh']} — `{r['variant']}`",
            "",
            f"*Hypothesis:* {HYPOTHESES.get(r['variant'], '(see perf.py)')}",
            "",
        ]
        for sname, s in r["steps"].items():
            if not s.get("ok"):
                lines.append(f"* `{sname}`: FAILED — {s.get('error','')[:200]}")
                continue
            # paged serve steps compare against their dense-cache twins
            b = (base["steps"].get(sname)
                 or base["steps"].get(sname.replace("paged_", ""))
                 ) if base else None
            if b and b.get("ok"):
                def delta(key):
                    if b[key] == 0:
                        return "n/a"
                    return f"{(s[key]-b[key])/b[key]*100:+.1f}%"
                lines.append(
                    f"* `{sname}`: before {fmt_step(b)} → after {fmt_step(s)}"
                    f" — Δcomp {delta('compute_s')}, Δmem {delta('memory_s')},"
                    f" Δcoll {delta('collective_s')}"
                )
                dom = b["dominant"]
                key = f"{dom}_s"
                verdict = (
                    "CONFIRMED" if s[key] < b[key] * 0.95
                    else ("neutral" if s[key] < b[key] * 1.05 else "REFUTED")
                )
                lines.append(f"  * dominant-term ({dom}) verdict: **{verdict}**")
            else:
                lines.append(f"* `{sname}`: {fmt_step(s)} (no baseline found)")
            db = s.get("decode_bound")
            if db:
                lines.append(
                    f"  * streaming floor (`decode_bandwidth_bound_s`): paged "
                    f"pool {db['kv_bytes']/1e9:.0f} GB live KV → "
                    f"{db['bound_s']*1e3:.2f} ms/step vs dense cache "
                    f"{db['dense_kv_bytes']/1e9:.0f} GB → "
                    f"{db['dense_bound_s']*1e3:.2f} ms/step "
                    f"(modeled step memory term {s['memory_s']*1e3:.2f} ms)"
                )
            ps = s.get("prefix_sharing")
            if ps:
                lines.append(
                    f"  * prefix sharing (`prefill_sharing_savings`, all "
                    f"slots on one shared prompt): "
                    f"{ps['tokens_saved']:.0f} of {ps['tokens_unshared']:.0f} "
                    f"prefill tokens skipped "
                    f"({ps['prefill_token_reduction']:.1f}× reduction) → "
                    f"{ps['flops_saved']/1e12:.1f} TFLOP and "
                    f"{ps['kv_write_bytes_saved']/1e9:.2f} GB of KV writes "
                    f"saved ≈ {ps['saved_s']*1e3:.2f} ms of prefill"
                )
        lines.append("")
        entries.append("\n".join(lines))

    body = "\n".join(entries) if entries else "(no perf runs recorded yet)"
    if os.path.exists(EXP):
        with open(EXP) as f:
            text = f.read()
    else:
        text = EXP_SKELETON
    if "<!-- COMPRESSION_BENCH -->" not in text:
        text += "\n## Compression engine\n\n<!-- COMPRESSION_BENCH -->\n"
    if "<!-- ROUNDSTEP_BENCH -->" not in text:
        text += "\n## Round pipeline\n\n<!-- ROUNDSTEP_BENCH -->\n"
    if "<!-- PP_BENCH -->" not in text:
        text += "\n## Federated partial participation\n\n<!-- PP_BENCH -->\n"
    if "<!-- ROBUST_BENCH -->" not in text:
        text += "\n## Byzantine robustness\n\n<!-- ROBUST_BENCH -->\n"
    if "<!-- ASYNC_BENCH -->" not in text:
        text += ("\n## Straggler-tolerant async rounds\n\n"
                 "<!-- ASYNC_BENCH -->\n")
    if "<!-- SERVE_BENCH -->" not in text:
        text += "\n## Serving\n\n<!-- SERVE_BENCH -->\n"
    text = _splice(text, "<!-- PERF_LOG -->", body)
    text = _splice(text, "<!-- COMPRESSION_BENCH -->", render_compression_bench())
    text = _splice(text, "<!-- ROUNDSTEP_BENCH -->", render_roundstep_bench())
    text = _splice(text, "<!-- PP_BENCH -->", render_pp_bench())
    text = _splice(text, "<!-- ROBUST_BENCH -->", render_robust_bench())
    text = _splice(text, "<!-- ASYNC_BENCH -->", render_async_bench())
    text = _splice(text, "<!-- SERVE_BENCH -->", render_serve_bench())
    with open(EXP, "w") as f:
        f.write(text)
    print(f"rendered {len(entries)} perf entries + compression + roundstep "
          "+ federated-pp + robust + async + serve bench")


if __name__ == "__main__":
    main()
