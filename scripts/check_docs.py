"""Docs CI: intra-repo markdown links must resolve, and the README
quickstart must run as-is.

* Link check — every ``[text](target)`` in README/DESIGN/EXPERIMENTS/
  ROADMAP/PAPERS/CHANGES is resolved relative to the repo root (and the
  containing file); http(s)/mailto links are skipped; ``#anchor`` fragments
  are checked against the target file's headings (GitHub slug rules,
  best-effort).
* Quickstart check — the FIRST ```python fenced block in README.md is
  extracted verbatim and executed with PYTHONPATH=src; a non-zero exit
  fails the job. The snippet the README shows is the snippet that runs.

Run: python scripts/check_docs.py
"""

from __future__ import annotations

import os
import re
import subprocess
import sys
import tempfile

ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
DOCS = ["README.md", "DESIGN.md", "EXPERIMENTS.md", "ROADMAP.md",
        "PAPERS.md", "CHANGES.md"]

_LINK = re.compile(r"(?<!!)\[[^\]]+\]\(([^)\s]+)\)")


def _slug(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, strip punctuation, dashes."""
    s = heading.strip().lower()
    s = re.sub(r"[`*_]", "", s)
    s = re.sub(r"[^\w\- ]", "", s, flags=re.UNICODE)
    return s.replace(" ", "-")


def _headings(path: str) -> set:
    out = set()
    with open(path) as f:
        in_code = False
        for line in f:
            if line.startswith("```"):
                in_code = not in_code
            if not in_code and line.startswith("#"):
                out.add(_slug(line.lstrip("#")))
    return out


def check_links() -> list:
    errors = []
    for doc in DOCS:
        path = os.path.join(ROOT, doc)
        if not os.path.exists(path):
            errors.append(f"{doc}: file missing")
            continue
        text = open(path).read()
        # strip fenced code blocks — links inside code are not navigation
        text = re.sub(r"```.*?```", "", text, flags=re.S)
        for target in _LINK.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            frag = None
            if "#" in target:
                target, frag = target.split("#", 1)
            if target:
                cand = os.path.normpath(os.path.join(ROOT, target))
                if not os.path.exists(cand):
                    cand = os.path.normpath(
                        os.path.join(os.path.dirname(path), target)
                    )
                if not os.path.exists(cand):
                    errors.append(f"{doc}: broken link -> {target}")
                    continue
            else:
                cand = path
            if frag and cand.endswith(".md"):
                if _slug(frag) not in {_slug(h) for h in _headings(cand)}:
                    errors.append(f"{doc}: broken anchor -> {target}#{frag}")
    return errors


def check_quickstart() -> list:
    readme = open(os.path.join(ROOT, "README.md")).read()
    m = re.search(r"```python\n(.*?)```", readme, re.S)
    if not m:
        return ["README.md: no ```python quickstart block found"]
    with tempfile.NamedTemporaryFile(
        "w", suffix="_quickstart.py", delete=False
    ) as f:
        f.write(m.group(1))
        snippet = f.name
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    out = subprocess.run(
        [sys.executable, snippet], capture_output=True, text=True, env=env,
        timeout=600,
    )
    os.unlink(snippet)
    if out.returncode != 0:
        return [f"README quickstart failed:\n{out.stderr[-2000:]}"]
    last = (out.stdout.strip().splitlines() or ["<no output>"])[-1]
    print(f"quickstart ran: {last}")
    return []


def main():
    errors = check_links()
    errors += check_quickstart()
    if errors:
        print("DOCS CHECK FAILED:", file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        sys.exit(1)
    print(f"docs check OK ({len(DOCS)} files, links + quickstart)")


if __name__ == "__main__":
    main()
