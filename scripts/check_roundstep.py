"""CI regression gate for the round-step benchmark.

Compares a fresh BENCH_roundstep.json (written by
`python -m benchmarks.run --only roundstep --quick` on the CI runner)
against the committed baseline and fails if the compressed round regressed
more than the threshold.

Absolute microseconds are NOT comparable across runners (CI machines differ
wildly from the box that committed the baseline), so the gate is on the
*within-run* normalized metric

    carry_over_sync = carry_fused_us / sync_us

— both sides of the ratio are measured interleaved in the same process, so
machine speed and transient load divide out; what remains is the relative
cost of the compressed round against the sync round, which is exactly what
this PR's pipeline work (one backprop, fused epilogue) pins down. A >25%
increase in that ratio on any matching (d, n) entry fails the job. The
two-backprop ratio is checked at the same threshold so the seed path cannot
silently rot either.

Multiple fresh JSONs may be passed; the gate takes the per-metric MINIMUM
across them (CI runs the quick bench twice). Load noise only ever slows a
run, so the min across independent runs is the honest estimate and keeps
the tight 25% threshold from false-failing on one unlucky draw (single
quick runs on a 2-core container swing ±30%).

Usage: python scripts/check_roundstep.py [fresh.json ...] [--baseline path]
"""

from __future__ import annotations

import json
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")
THRESHOLD = 1.25  # fail if fresh ratio > baseline ratio * 1.25

METRICS = ("carry_over_sync", "two_backprop_over_sync")


def load(path):
    with open(path) as f:
        return json.load(f)


def main():
    args = sys.argv[1:]
    base_path = os.path.join(ROOT, "benchmarks", "roundstep_baseline.json")
    if "--baseline" in args:
        i = args.index("--baseline")
        base_path = args[i + 1]
        args = args[:i] + args[i + 2:]
    fresh_paths = args or [os.path.join(ROOT, "BENCH_roundstep.json")]
    freshes, base = [load(p) for p in fresh_paths], load(base_path)
    base_by_key = {(e["d"], e["n"]): e for e in base["entries"]}

    # per-metric min across the fresh runs (noise only ever slows a run)
    fresh_by_key = {}
    for f in freshes:
        for e in f["entries"]:
            cur = fresh_by_key.setdefault((e["d"], e["n"]), dict(e))
            for m in METRICS:
                cur[m] = min(cur[m], e[m])

    failures = []
    checked = 0
    for (d, n), e in sorted(fresh_by_key.items()):
        b = base_by_key.get((d, n))
        if b is None:
            continue
        for m in METRICS:
            checked += 1
            ratio = e[m] / b[m]
            status = "OK" if ratio <= THRESHOLD else "REGRESSED"
            print(
                f"d={d:>7} n={n:>2} {m}: baseline {b[m]:.3f} "
                f"fresh {e[m]:.3f} ({ratio:.2f}x) {status}"
            )
            if ratio > THRESHOLD:
                failures.append((d, n, m, ratio))

    if not checked:
        print("ERROR: no (d, n) entries matched the baseline", file=sys.stderr)
        return 2
    if failures:
        print(
            f"FAIL: compressed-round step time regressed >25% vs the "
            f"committed baseline on {len(failures)} entr"
            f"{'y' if len(failures) == 1 else 'ies'}",
            file=sys.stderr,
        )
        return 1
    print(f"roundstep gate passed ({checked} checks)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
