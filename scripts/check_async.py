"""CI gate for the deadline-cohort async path (DESIGN.md §4.10).

Runs the two equivalence contracts of core/async_rounds.py at test scale
and fails the job when either stops holding BITWISE:

1. **p_miss = 0** — a deadline no client can ever miss must leave
   ``DeadlineMarina`` bit-identical to ``Marina(carry=True)``: the
   (k_bern, k_q) key split is untouched (round-time randomness rides the
   ``TIME_FOLD`` side channel) and the diff rows coincide, so any drift
   here means a refactor broke the key discipline or reordered the
   iterate update (the in-branch-axpy XLA-fusion trap).

2. **static slow set, tau_max = 0** — clients that ALWAYS miss the
   deadline and are never accepted late must reproduce the static
   ``FaultSpec("drop", ids=...)`` carry substitution exactly: Δ̂_i = 0
   rows, no h refresh, and the uploaded·ζ_Q/n billing.

Bitwise (not allclose) on purpose: both sides run the same op sequence in
one process, so ANY difference is a semantics change, not float noise.

Usage: PYTHONPATH=src python scripts/check_async.py
"""

from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

N, M, D = 6, 32, 24
ROUNDS = 40
SLOW = (1, 4)


def run_pair(label, method_a, method_b, steps=ROUNDS, seed=7):
    from repro.core.problems import make_synthetic_binclass, nonconvex_binclass_loss

    data = make_synthetic_binclass(jax.random.PRNGKey(0), N, M, D)
    x0 = jnp.zeros((D,))
    sa = method_a.init(x0, data)
    sb = method_b.init(x0, data)
    step_a = jax.jit(method_a.step)
    step_b = jax.jit(method_b.step)
    bits_a = bits_b = 0.0
    for k in range(steps):
        key = jax.random.PRNGKey(seed * 100_000 + k)
        sa, ma = step_a(sa, key, data)
        sb, mb = step_b(sb, key, data)
        bits_a += float(ma.bits_per_worker)
        bits_b += float(mb.bits_per_worker)
        for name in ("params", "g"):
            va = np.asarray(getattr(sa, name))
            vb = np.asarray(getattr(sb, name))
            if not np.array_equal(va, vb):
                print(f"{label}: {name} DIVERGED at round {k} "
                      f"(max |Δ| = {np.max(np.abs(va - vb)):.3e})",
                      file=sys.stderr)
                return False
    if bits_a != bits_b:
        print(f"{label}: ledger drift — {bits_a} vs {bits_b} bits/worker",
              file=sys.stderr)
        return False
    print(f"{label}: {steps} rounds bit-identical "
          f"({bits_a:.0f} bits/worker booked on both sides)")
    return True


def main():
    from repro.core import (
        DeadlineMarina,
        FaultSpec,
        Marina,
        RandK,
        RoundTimeModel,
    )
    from repro.core.problems import nonconvex_binclass_loss

    grad = jax.grad(nonconvex_binclass_loss)
    comp = RandK(k=3)
    gamma, p = 0.05, 0.3

    ok = run_pair(
        "p_miss=0 (never-miss deadline == full participation)",
        DeadlineMarina(grad, comp, gamma, p, deadline=1e9,
                       times=RoundTimeModel(dist="fixed", mean_s=1.0)),
        Marina(grad, comp, gamma, p, carry=True),
    )
    ok &= run_pair(
        "static slow set (always-miss == FaultSpec drop)",
        DeadlineMarina(
            grad, comp, gamma, p, deadline=2.0,
            times=RoundTimeModel(dist="fixed", mean_s=1.0,
                                 slow_ids=SLOW, slow_factor=8.0),
        ),
        Marina(grad, comp, gamma, p, carry=True,
               faults=FaultSpec("drop", ids=SLOW)),
    )

    if not ok:
        print("FAIL: async equivalence gate", file=sys.stderr)
        return 1
    print("async gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
