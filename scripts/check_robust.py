"""CI gate for the Byzantine-robust aggregation path (DESIGN.md §4.9).

Reads the ``robust`` section of BENCH_pp.json (written by
`python -m benchmarks.run --only robust --quick` on the CI runner) and
fails the job when either claim of the robustness PR stops holding:

1. **Round-time** — the robust fused round must stay within the threshold
   of the fused mean round: ``round_{trimmed,median}_over_mean <= 1.25``.
   The ratio is within-run (both sides measured interleaved in one
   process), so machine speed divides out, exactly like the roundstep
   gate. The *isolated* sync-epilogue ratio is recorded in the JSON but
   deliberately NOT gated: on the CPU ref backend the mean epilogue is one
   memory-bound pass while the trimmed rule is a compute-bound O(n²/2)
   compare-exchange network, so their ratio measures the container's
   FLOP/byte balance, not a regression (the ~1.2× epilogue claim is the
   TPU Pallas kernel's, where the extra compares ride in-register on the
   same HBM traffic).

2. **Semantics** — at the largest attacked fraction in the grid, every
   coordinate-wise GAR must beat the plain mean on final honest loss under
   both payload attacks (sign_flip, mean_shift), and every cell must be
   finite. If a refactor breaks the trim window, the fault masking, or the
   carry substitution, this is the check that notices before EXPERIMENTS.md
   advertises stale numbers.

Usage: python scripts/check_robust.py [BENCH_pp.json ...]
(multiple files: per-metric MINIMUM for the timing gate — load noise only
ever slows a run — and every file checked for semantics.)
"""

from __future__ import annotations

import json
import math
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")
ROUND_THRESHOLD = 1.25
ROBUST_GARS = ("trimmed_mean", "coordinate_median")
PAYLOAD_ATTACKS = ("sign_flip", "mean_shift")


def load(path):
    with open(path) as f:
        return json.load(f)


def check_roundtime(robusts):
    failures = []
    for metric in ("round_trimmed_over_mean", "round_median_over_mean"):
        ratio = min(r["roundtime"][metric] for r in robusts)
        status = "OK" if ratio <= ROUND_THRESHOLD else "REGRESSED"
        print(f"roundtime {metric}: {ratio:.2f}x (limit "
              f"{ROUND_THRESHOLD}) {status}")
        if ratio > ROUND_THRESHOLD:
            failures.append(metric)
    return failures


def check_grid(robust):
    failures = []
    cells = robust["cells"]
    for c in cells:
        if not math.isfinite(c["final_loss"]):
            failures.append(f"non-finite loss in cell {c['attack']}/"
                            f"{c['gar']}@{c['frac']}")
    by = {(c["attack"], c["frac"], c["gar"]): c for c in cells}
    top = max(c["frac"] for c in cells if c["attack"] in PAYLOAD_ATTACKS)
    for attack in PAYLOAD_ATTACKS:
        mean_cell = by.get((attack, top, "mean"))
        if mean_cell is None:
            failures.append(f"missing mean cell for {attack}@{top}")
            continue
        for gar in ROBUST_GARS:
            cell = by.get((attack, top, gar))
            if cell is None:
                failures.append(f"missing {gar} cell for {attack}@{top}")
                continue
            ok = cell["final_loss"] < mean_cell["final_loss"]
            print(f"grid {attack}@{top} {gar}: loss {cell['final_loss']:.4f} "
                  f"vs mean {mean_cell['final_loss']:.4f} "
                  f"{'OK' if ok else 'NOT ROBUST'}")
            if not ok:
                failures.append(f"{gar} no better than mean under "
                                f"{attack}@{top}")
    return failures


def main():
    paths = sys.argv[1:] or [os.path.join(ROOT, "BENCH_pp.json")]
    robusts = []
    for p in paths:
        r = load(p).get("robust")
        if r is None:
            print(f"ERROR: {p} has no 'robust' section — run "
                  "`python -m benchmarks.run --only robust`", file=sys.stderr)
            return 2
        robusts.append(r)

    failures = check_roundtime(robusts)
    for r in robusts:
        failures += check_grid(r)

    if failures:
        print("FAIL: " + "; ".join(failures), file=sys.stderr)
        return 1
    print("robust gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
