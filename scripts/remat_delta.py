"""§Perf iteration 1 table: whole-loss remat (v0) vs per-layer remat + (R,L)
compression layout (v1), per train combo. Prints a markdown table."""

import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")
V0 = os.path.join(ROOT, "experiments", "dryrun_v0")
V1 = os.path.join(ROOT, "experiments", "dryrun")


def pick(d, arch, mesh):
    p = os.path.join(d, f"{arch}__train_4k__{mesh}.json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def main():
    archs = sorted(
        {os.path.basename(f).split("__")[0] for f in glob.glob(V0 + "/*train_4k*")}
    )
    print("| arch | mesh | step | mem_ms v0→v1 | coll_ms v0→v1 | temp GB v0→v1 |")
    print("|---|---|---|---|---|---|")
    for arch in archs:
        for mesh in ("single", "multi"):
            r0, r1 = pick(V0, arch, mesh), pick(V1, arch, mesh)
            if not (r0 and r1):
                continue
            for sname in ("sync_step", "compressed_step"):
                s0 = r0["steps"].get(sname, {})
                s1 = r1["steps"].get(sname, {})
                if not (s0.get("ok") and s1.get("ok")):
                    continue
                t0 = s0.get("memory_analysis", {}).get("temp_size_in_bytes", 0) / 1e9
                t1 = s1.get("memory_analysis", {}).get("temp_size_in_bytes", 0) / 1e9
                print(
                    f"| {arch} | {mesh} | {sname} "
                    f"| {s0['memory_s']*1e3:.0f} → {s1['memory_s']*1e3:.0f} "
                    f"| {s0['collective_s']*1e3:.0f} → {s1['collective_s']*1e3:.0f} "
                    f"| {t0:.1f} → {t1:.1f} |"
                )


if __name__ == "__main__":
    main()
