"""One CI entry point: run every repo gate, then the α–β disagreement sweep.

Consolidates the four standalone checks (ISSUE 7 satellite) so CI and a
local pre-push run invoke ONE script with one summary line per gate:

* ``roundstep`` — scripts/check_roundstep.py (compressed-round regression
  gate vs the committed baseline; pass fresh JSONs via ``--roundstep``),
* ``serve``     — scripts/check_serve.py (continuous/static tokens/s ratio
  vs the committed baseline, the shared-prefix win — tokens/s OR
  prefill-token reduction — and the tight-pool preemption section; pass
  fresh JSONs via ``--serve``),
* ``robust``    — scripts/check_robust.py (robust-GAR round-time + semantics),
* ``async``     — scripts/check_async.py (deadline-cohort bit-identity:
  p_miss=0 ≡ full participation, static-slow ≡ FaultSpec drop),
* ``docs``      — scripts/check_docs.py (markdown links + README quickstart),
* ``api_docs``  — scripts/check_api_docs.py (public-surface docstrings).

Each check still works standalone — this script shells out to them (they
own sys.argv/sys.exit and the api_docs/docs checks import jax, which must
not contaminate one shared interpreter with device state).

After the gates, the α–β disagreement sweep (roofline/analysis.py,
DESIGN.md §7) walks experiments/perf/*.json: every recorded step that
carries both the flat-ici collective term and the per-tier α–β term gets a
CONFIRMED/REFUTED verdict at the >2× threshold. The sweep is REFUTED-style
*reporting*, not a gate — a REFUTED row means the flat model mispriced that
variant's dominant link tier (exactly the insight the per-tier model adds),
not that the repo regressed. Pre-ISSUE-7 JSONs without per-tier data are
counted as skipped.

Usage:
    python scripts/check_all.py [--roundstep fresh.json ...]
                                [--skip roundstep,robust,docs,api_docs]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys

ROOT = os.path.normpath(os.path.join(os.path.dirname(__file__), ".."))
SCRIPTS = os.path.dirname(os.path.abspath(__file__))
PERF = os.path.join(ROOT, "experiments", "perf")


def run_check(name: str, argv: list, needs_src_path: bool = False) -> bool:
    env = dict(os.environ)
    if needs_src_path:
        env["PYTHONPATH"] = os.path.join(ROOT, "src")
    print(f"== {name} ==", flush=True)
    proc = subprocess.run(argv, cwd=ROOT, env=env)
    ok = proc.returncode == 0
    print(f"== {name}: {'OK' if ok else 'FAIL'} ==", flush=True)
    return ok


def alpha_beta_sweep(factor: float = 2.0) -> None:
    """Flat-ici vs per-tier α–β verdict for every recorded perf step."""
    sys.path.insert(0, os.path.join(ROOT, "src"))
    from repro.roofline import alpha_beta_disagreement

    rows, skipped = [], 0
    for path in sorted(glob.glob(os.path.join(PERF, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        tag = f"{r.get('arch')}__{r.get('shape')}__{r.get('mesh')}__{r.get('variant')}"
        for sname, s in r.get("steps", {}).items():
            if not s.get("ok"):
                continue
            flat, tiered = s.get("collective_s_flat"), s.get("collective_s")
            if flat is None:  # pre-ISSUE-7 JSON: no per-tier classification
                skipped += 1
                continue
            v = alpha_beta_disagreement(flat, tiered, factor=factor)
            if v is None:
                skipped += 1
                continue
            rows.append((tag, sname, flat, tiered, v))
    print(f"== alpha-beta sweep ({len(rows)} steps, {skipped} skipped) ==")
    for tag, sname, flat, tiered, v in rows:
        print(
            f"  {v['verdict']:9s} {tag}/{sname}: flat {flat*1e3:.2f} ms vs "
            f"a-b {tiered*1e3:.2f} ms ({v['ratio']:.2f}x)"
        )
    refuted = sum(1 for *_r, v in rows if v["verdict"] == "REFUTED")
    if refuted:
        print(
            f"  note: {refuted} REFUTED — the flat model mispriced those "
            "variants' dominant link tier (reporting only, not a gate)"
        )


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--roundstep", nargs="*", default=None, metavar="JSON",
        help="fresh BENCH_roundstep.json files for the regression gate "
        "(default: the repo-root BENCH_roundstep.json)",
    )
    ap.add_argument(
        "--serve", nargs="*", default=None, metavar="JSON",
        help="fresh BENCH_serve.json files for the serving gate "
        "(default: the repo-root BENCH_serve.json)",
    )
    ap.add_argument(
        "--skip", default="", metavar="NAMES",
        help="comma-separated gates to skip (e.g. docs-only runners: "
        "--skip roundstep,robust,async)",
    )
    args = ap.parse_args()
    skip = {s.strip() for s in args.skip.split(",") if s.strip()}

    py = sys.executable
    checks = {
        "roundstep": (
            [py, os.path.join(SCRIPTS, "check_roundstep.py"),
             *(args.roundstep or [])],
            False,
        ),
        "serve": (
            [py, os.path.join(SCRIPTS, "check_serve.py"),
             *(args.serve or [])],
            False,
        ),
        "robust": ([py, os.path.join(SCRIPTS, "check_robust.py")], False),
        "async": ([py, os.path.join(SCRIPTS, "check_async.py")], True),
        "docs": ([py, os.path.join(SCRIPTS, "check_docs.py")], False),
        "api_docs": ([py, os.path.join(SCRIPTS, "check_api_docs.py")], True),
    }

    results = {}
    for name, (argv, needs_src) in checks.items():
        if name in skip:
            print(f"== {name}: SKIPPED ==")
            continue
        results[name] = run_check(name, argv, needs_src)

    alpha_beta_sweep()

    failed = [n for n, ok in results.items() if not ok]
    if failed:
        print(f"CHECK_ALL FAILED: {', '.join(failed)}", file=sys.stderr)
        return 1
    print(f"check_all OK ({len(results)} gates" +
          (f", {len(skip)} skipped" if skip else "") + ")")
    return 0


if __name__ == "__main__":
    sys.exit(main())
