"""CI regression gate for the serving benchmark.

Compares a fresh BENCH_serve.json (written by
`python -m benchmarks.run --only serve --quick` on the CI runner) against
the committed baseline and fails if continuous batching lost more than 25%
of its advantage over static batching.

Absolute tokens/s are NOT comparable across runners, so the gate is on the
*within-run* normalized metric

    continuous_over_static = continuous tokens/s / static tokens/s

— both modes run the same workload in the same process, so machine speed
divides out; what remains is the scheduling win the paged engine exists to
deliver (slot backfill vs decode-at-the-pace-of-the-longest). A fresh ratio
below ``baseline * 0.75`` fails the job.

Multiple fresh JSONs may be passed; the gate takes the MAXIMUM ratio across
them — transient load depresses whichever mode it lands on, so the best of
several runs is the honest estimate of the machine-independent ratio.

Usage: python scripts/check_serve.py [fresh.json ...] [--baseline path]
"""

from __future__ import annotations

import json
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")
THRESHOLD = 0.75  # fail if fresh ratio < baseline ratio * 0.75

METRIC = "continuous_over_static"


def load(path):
    with open(path) as f:
        return json.load(f)


def main():
    args = sys.argv[1:]
    base_path = os.path.join(ROOT, "benchmarks", "serve_baseline.json")
    if "--baseline" in args:
        i = args.index("--baseline")
        base_path = args[i + 1]
        args = args[:i] + args[i + 2:]
    fresh_paths = args or [os.path.join(ROOT, "BENCH_serve.json")]
    freshes, base = [load(p) for p in fresh_paths], load(base_path)

    fresh = max(f[METRIC] for f in freshes)
    floor = base[METRIC] * THRESHOLD
    status = "OK" if fresh >= floor else "REGRESSED"
    print(
        f"{METRIC}: baseline {base[METRIC]:.2f}x, fresh {fresh:.2f}x "
        f"(floor {floor:.2f}x) {status}"
    )
    if fresh < floor:
        print(
            "FAIL: continuous batching lost >25% of its tokens/s advantage "
            "over static batching vs the committed baseline",
            file=sys.stderr,
        )
        return 1
    print("serve gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
