"""CI regression gate for the serving benchmark.

Compares a fresh BENCH_serve.json (written by
`python -m benchmarks.run --only serve --quick` on the CI runner) against
the committed baseline and fails if continuous batching lost more than 25%
of its advantage over static batching.

Absolute tokens/s are NOT comparable across runners, so the gate is on the
*within-run* normalized metric

    continuous_over_static = continuous tokens/s / static tokens/s

— both modes run the same workload in the same process, so machine speed
divides out; what remains is the scheduling win the paged engine exists to
deliver (slot backfill vs decode-at-the-pace-of-the-longest). A fresh ratio
below ``baseline * 0.75`` fails the job.

A second gate covers the prefix-sharing section: sharing must keep EITHER
a >=1.5x tokens/s win over the unshared run OR a >=2x reduction in prompt
tokens actually prefilled. The token reduction is deterministic arithmetic
(scheduler bookkeeping, no wall clock), so it is the reliable leg; the
tokens/s ratio leg exists so a future change that keeps the bookkeeping
but destroys the win (e.g. COW-splitting every page) still trips the gate.

Multiple fresh JSONs may be passed; the gate takes the MAXIMUM ratio across
them — transient load depresses whichever mode it lands on, so the best of
several runs is the honest estimate of the machine-independent ratio.

Usage: python scripts/check_serve.py [fresh.json ...] [--baseline path]
"""

from __future__ import annotations

import json
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")
THRESHOLD = 0.75  # fail if fresh ratio < baseline ratio * 0.75

METRIC = "continuous_over_static"

# prefix-sharing floors (absolute, within-run): pass if EITHER holds
SHARED_TOKPS_FLOOR = 1.5
SHARED_PREFILL_FLOOR = 2.0


def load(path):
    with open(path) as f:
        return json.load(f)


def main():
    args = sys.argv[1:]
    base_path = os.path.join(ROOT, "benchmarks", "serve_baseline.json")
    if "--baseline" in args:
        i = args.index("--baseline")
        base_path = args[i + 1]
        args = args[:i] + args[i + 2:]
    fresh_paths = args or [os.path.join(ROOT, "BENCH_serve.json")]
    freshes, base = [load(p) for p in fresh_paths], load(base_path)

    fail = 0

    fresh = max(f[METRIC] for f in freshes)
    floor = base[METRIC] * THRESHOLD
    status = "OK" if fresh >= floor else "REGRESSED"
    print(
        f"{METRIC}: baseline {base[METRIC]:.2f}x, fresh {fresh:.2f}x "
        f"(floor {floor:.2f}x) {status}"
    )
    if fresh < floor:
        print(
            "FAIL: continuous batching lost >25% of its tokens/s advantage "
            "over static batching vs the committed baseline",
            file=sys.stderr,
        )
        fail = 1

    sections = [f.get("shared_prefix") for f in freshes]
    sections = [s for s in sections if s]
    if not sections:
        print("FAIL: no fresh run carries a shared_prefix section", file=sys.stderr)
        fail = 1
    else:
        tokps = max(s["shared_over_unshared"] for s in sections)
        red = max(s["prefill_token_reduction"] for s in sections)
        ok = tokps >= SHARED_TOKPS_FLOOR or red >= SHARED_PREFILL_FLOOR
        print(
            f"shared_prefix: tokens/s {tokps:.2f}x (floor {SHARED_TOKPS_FLOOR}x), "
            f"prefill reduction {red:.2f}x (floor {SHARED_PREFILL_FLOOR}x) "
            f"{'OK' if ok else 'REGRESSED'}"
        )
        if not ok:
            print(
                "FAIL: prefix sharing delivers neither a >=1.5x tokens/s win "
                "nor a >=2x prefill-token reduction",
                file=sys.stderr,
            )
            fail = 1

    pre = [f.get("preemption") for f in freshes]
    pre = [p for p in pre if p]
    if not pre:
        print("FAIL: no fresh run carries a preemption section", file=sys.stderr)
        fail = 1
    elif any(p["preemptions"] < 1 for p in pre):
        print("FAIL: the tight-pool run did not preempt", file=sys.stderr)
        fail = 1
    else:
        print(
            f"preemption: {min(p['preemptions'] for p in pre)}+ preemptions, "
            f"all {pre[0]['n_requests']} requests completed OK"
        )

    if not fail:
        print("serve gate passed")
    return fail


if __name__ == "__main__":
    sys.exit(main())
